//! Shared fixed-point compute kernels — the one place conv math happens.
//!
//! The paper's core trick is *depth flattening*: all input channels of a
//! window are consumed in one pipelined burst instead of one channel at a
//! time. This module is the software mirror of that dataflow, structured the
//! way the FPGA CNN surveys describe the canonical CPU lowering:
//!
//! * [`im2col`] lowers windows into a **depth-major scratch row** — exactly
//!   the paper's depth-concatenated word layout, `buf[tap·d + c]` — so the
//!   whole receptive field of an output pixel is one contiguous burst;
//! * [`mac`] runs a **cache-blocked, depth-flattened MAC kernel** over those
//!   rows: the inner loop walks the full `k²·d` patch of a window while a
//!   4×4 register tile unrolls over output pixels × output filters, with
//!   weights packed patch-major ([`mac::PackedFilters`], one unit-stride
//!   stream);
//! * [`conv2d_fx`] adds **scoped-thread row parallelism**
//!   (`std::thread::scope`) over disjoint output-row bands.
//!
//! Every consumer — [`crate::accel::Engine::forward_fx`], the baseline
//! models' functional forwards (`baselines::optimized::forward_fx`,
//! `baselines::fused_layer::forward_fx`) — routes through [`conv2d_fx`], so
//! there is exactly one compute implementation. [`naive::conv2d_fx_naive`]
//! keeps the textbook one-pixel/one-channel walk as the bit-exact oracle
//! (and the "before" side of `benches/compute_kernels.rs`), while
//! `baselines::cpu_ref` remains the independent f32 oracle.
//!
//! ## Bit-exactness
//!
//! The Q16.16 datapath accumulates full-width `i64` partial products
//! ([`crate::tensor::fixed::MacAcc`]) and quantizes once per output. For
//! every (pixel, filter) pair, both the naive walk and the blocked kernel
//! accumulate the patch in the same ascending `tap·d + c` order with the
//! same saturating adds, so the results are bit-identical by construction —
//! including the (astronomically rare) mid-sum saturation cases that a
//! reordered reduction could disturb. `tests/integration_compute.rs` pins
//! this down over randomized shapes.

pub mod im2col;
pub mod mac;
pub mod naive;

use std::num::NonZeroUsize;
use std::ops::Range;

use crate::accel::depth_concat::FilterBanks;
use crate::accel::pool::PoolUnit;
use crate::config::{Layer, Network};
use crate::tensor::fixed::Fx;
use crate::tensor::FxTensor;

use self::im2col::im2col_band;
use self::mac::{mac_band, PackedFilters};

use super::engine::Weights;

/// Geometry of one conv layer as the kernels see it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvGeom {
    /// Input extent and depth.
    pub h: usize,
    pub w: usize,
    pub d: usize,
    /// Kernel extent (square), zero padding, output filters.
    pub kernel: usize,
    pub pad: usize,
    pub filters: usize,
}

impl ConvGeom {
    pub fn for_input(input: &FxTensor, banks: &FilterBanks, pad: usize) -> ConvGeom {
        let sh = input.shape();
        assert_eq!(sh.len(), 3, "conv input must be [h, w, d]");
        assert_eq!(sh[2], banks.d, "input depth must match the filter bank");
        assert!(pad < banks.w, "padding must be smaller than the kernel");
        assert!(
            sh[0] + 2 * pad >= banks.w && sh[1] + 2 * pad >= banks.w,
            "kernel exceeds the padded input"
        );
        ConvGeom {
            h: sh[0],
            w: sh[1],
            d: sh[2],
            kernel: banks.w,
            pad,
            filters: banks.k,
        }
    }

    pub fn out_h(&self) -> usize {
        self.h + 2 * self.pad - self.kernel + 1
    }

    pub fn out_w(&self) -> usize {
        self.w + 2 * self.pad - self.kernel + 1
    }

    /// Patch length: the depth-concatenated window, `kernel² · d` values.
    pub fn patch(&self) -> usize {
        self.kernel * self.kernel * self.d
    }
}

/// Reusable scratch for the kernel path: the im2col band buffer and the
/// packed filter matrix. One `KernelScratch` is allocated per forward pass
/// and reused across every layer (buffers only ever grow), mirroring the
/// paper's single depth-concatenation buffer that all layers stream through.
#[derive(Debug, Default)]
pub struct KernelScratch {
    /// Depth-major im2col rows for the current band: `[band_px][patch]`.
    col: Vec<Fx>,
    /// Per-worker im2col buffers for the scoped-thread path, one per row
    /// band — reused across layers just like `col`.
    bands: Vec<Vec<Fx>>,
    /// Patch-major packed weights for the current layer (see
    /// [`mac::PackedFilters`]).
    packed: PackedFilters,
}

impl KernelScratch {
    pub fn new() -> KernelScratch {
        KernelScratch::default()
    }

    /// Pack a layer's filters for the band API. [`conv2d_fx`] does this
    /// itself; callers walking a layer in tiles via [`conv2d_fx_rows`] pack
    /// once here and then run every tile against the same matrix, instead
    /// of paying a full repack per tile.
    pub fn pack_filters(&mut self, banks: &FilterBanks) {
        self.packed.pack(banks);
    }
}

/// Number of worker threads the kernel path uses by default: the
/// `DECOILFNET_THREADS` environment variable when set (CI pins it for
/// reproducible bench *structure*), otherwise the machine's available
/// parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("DECOILFNET_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(NonZeroUsize::get)
        .unwrap_or(1)
}

/// Cap a row band so its im2col buffer stays cache-resident (~256 KiB).
fn band_rows(geom: &ConvGeom) -> usize {
    const TARGET_BYTES: usize = 1 << 18;
    let row_bytes = geom.out_w() * geom.patch() * std::mem::size_of::<Fx>();
    (TARGET_BYTES / row_bytes.max(1)).clamp(1, geom.out_h().max(1))
}

/// Convolve one band of output rows `rows` into `out` (single threaded).
/// Exposed so tiled consumers — `baselines::optimized::forward_fx` walks its
/// roofline-chosen `Tr` row tiles through this — share the exact same kernel
/// as the whole-layer path.
///
/// Contract: the caller packs the layer's filters once with
/// [`KernelScratch::pack_filters`] before the tile loop (geometry is
/// asserted here; re-packing per tile would cost a full `patch·k` copy per
/// band for nothing).
pub fn conv2d_fx_rows(
    input: &FxTensor,
    banks: &FilterBanks,
    pad: usize,
    relu: bool,
    rows: Range<usize>,
    scratch: &mut KernelScratch,
    out: &mut FxTensor,
) {
    let geom = ConvGeom::for_input(input, banks, pad);
    assert_eq!(
        out.shape(),
        &[geom.out_h(), geom.out_w(), geom.filters],
        "output tensor shape mismatch"
    );
    assert!(rows.start <= rows.end && rows.end <= geom.out_h());
    assert_eq!(
        (scratch.packed.patch, scratch.packed.k),
        (geom.patch(), geom.filters),
        "pack_filters(banks) must run before the tile loop"
    );
    let ow = geom.out_w();
    let k = geom.filters;
    let row_stride = ow * k;
    let out_band = &mut out.data_mut()[rows.start * row_stride..rows.end * row_stride];
    conv_rows_into(input, &geom, relu, rows, &mut scratch.col, &scratch.packed, out_band);
}

/// Band worker shared by the single-thread and scoped-thread paths: lower
/// sub-bands of `rows` with im2col and run the blocked MAC kernel, writing
/// into `out_band` (the rows' slice of the output tensor).
fn conv_rows_into(
    input: &FxTensor,
    geom: &ConvGeom,
    relu: bool,
    rows: Range<usize>,
    col: &mut Vec<Fx>,
    packed: &PackedFilters,
    out_band: &mut [Fx],
) {
    let ow = geom.out_w();
    let k = geom.filters;
    let patch = geom.patch();
    let sub = band_rows(geom);
    let mut r = rows.start;
    while r < rows.end {
        let r_end = (r + sub).min(rows.end);
        let n_px = (r_end - r) * ow;
        col.clear();
        col.resize(n_px * patch, Fx::ZERO);
        im2col_band(input, geom, r..r_end, col);
        let off = (r - rows.start) * ow * k;
        mac_band(col, packed, patch, relu, &mut out_band[off..off + n_px * k]);
        r = r_end;
    }
}

/// Full conv layer through the shared kernel: im2col lowering, blocked
/// depth-flattened MAC, and (for `threads > 1`) scoped-thread parallelism
/// over disjoint output-row bands. Values are identical for every thread
/// count — threads only partition rows.
pub fn conv2d_fx(
    input: &FxTensor,
    banks: &FilterBanks,
    pad: usize,
    relu: bool,
    threads: usize,
    scratch: &mut KernelScratch,
) -> FxTensor {
    let geom = ConvGeom::for_input(input, banks, pad);
    let (oh, ow, k) = (geom.out_h(), geom.out_w(), geom.filters);
    let mut out = FxTensor::zeros(&[oh, ow, k]);
    scratch.packed.pack(banks);
    let threads = threads.clamp(1, oh.max(1));
    if threads <= 1 {
        conv_rows_into(
            input,
            &geom,
            relu,
            0..oh,
            &mut scratch.col,
            &scratch.packed,
            out.data_mut(),
        );
        return out;
    }
    // Contiguous row bands, one per worker; `chunks_mut` hands each thread a
    // disjoint slice of the output (no synchronization), and each worker
    // borrows its own scratch band buffer, reused across layers.
    let rows_per = oh.div_ceil(threads);
    let row_stride = ow * k;
    if scratch.bands.len() < threads {
        scratch.bands.resize_with(threads, Vec::new);
    }
    let packed = &scratch.packed;
    std::thread::scope(|scope| {
        let chunks = out.data_mut().chunks_mut(rows_per * row_stride);
        for ((t, chunk), col) in chunks.enumerate().zip(scratch.bands.iter_mut()) {
            let r0 = t * rows_per;
            let r1 = (r0 + chunk.len() / row_stride).min(oh);
            scope.spawn(move || {
                conv_rows_into(input, &geom, relu, r0..r1, col, packed, chunk);
            });
        }
    });
    out
}

/// Functional forward of a whole network through the shared kernels.
/// Fusion plans change data movement, never values, so this is the single
/// functional-forward implementation behind [`crate::accel::Engine`] and
/// both baseline models.
pub fn forward_network_fx(
    net: &Network,
    weights: &Weights,
    input: &FxTensor,
    threads: usize,
    scratch: &mut KernelScratch,
) -> FxTensor {
    let mut cur = input.clone();
    for (li, layer) in net.layers.iter().enumerate() {
        cur = match layer {
            Layer::Conv { padding, relu, .. } => {
                let banks = weights.banks[li].as_ref().expect("conv layer needs weights");
                conv2d_fx(&cur, banks, *padding, *relu, threads, scratch)
            }
            Layer::MaxPool { window, stride, .. } => PoolUnit::new(*window, *stride).forward(&cur),
        };
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_test_example;
    use crate::tensor::NdTensor;
    use crate::util::prng::Rng;
    use crate::util::prop;

    fn random_banks(rng: &mut Rng, k: usize, w: usize, d: usize) -> FilterBanks {
        let filt = NdTensor::random(&[k, w, w, d], rng.next_u64(), -0.5, 0.5);
        let bias = NdTensor::random(&[k], rng.next_u64(), -0.1, 0.1);
        FilterBanks::from_tensor(&filt, &bias)
    }

    #[test]
    fn geom_shapes() {
        let mut rng = Rng::new(1);
        let banks = random_banks(&mut rng, 4, 3, 2);
        let input = NdTensor::random(&[6, 5, 2], 2, -1.0, 1.0).to_fixed();
        let g = ConvGeom::for_input(&input, &banks, 1);
        assert_eq!((g.out_h(), g.out_w()), (6, 5));
        assert_eq!(g.patch(), 9 * 2);
        let g0 = ConvGeom::for_input(&input, &banks, 0);
        assert_eq!((g0.out_h(), g0.out_w()), (4, 3));
    }

    #[test]
    fn kernel_matches_naive_on_random_shapes() {
        prop::check_default(
            "kernels-vs-naive",
            |r: &mut Rng| {
                let h = r.range_usize(3, 12);
                let w = r.range_usize(3, 12);
                let d = r.range_usize(1, 9);
                let k = r.range_usize(1, 9);
                let pad = r.range_usize(0, 2);
                (h, w, d, k, pad, r.chance(0.5), r.next_u64())
            },
            |&(h, w, d, k, pad, relu, seed)| {
                let mut rng = Rng::new(seed);
                let banks = random_banks(&mut rng, k, 3, d);
                let input = NdTensor::random(&[h, w, d], seed ^ 5, -1.0, 1.0).to_fixed();
                let mut scratch = KernelScratch::new();
                let fast = conv2d_fx(&input, &banks, pad, relu, 1, &mut scratch);
                let slow = naive::conv2d_fx_naive(&input, &banks, pad, relu);
                if fast == slow {
                    Ok(())
                } else {
                    Err("kernel diverged from the naive oracle".to_string())
                }
            },
        );
    }

    #[test]
    fn threading_never_changes_values() {
        let mut rng = Rng::new(7);
        let banks = random_banks(&mut rng, 6, 3, 5);
        let input = NdTensor::random(&[17, 11, 5], 9, -1.0, 1.0).to_fixed();
        let mut scratch = KernelScratch::new();
        let one = conv2d_fx(&input, &banks, 1, true, 1, &mut scratch);
        for threads in [2, 3, 8, 64] {
            let t = conv2d_fx(&input, &banks, 1, true, threads, &mut scratch);
            assert_eq!(one, t, "threads={threads} changed values");
        }
    }

    #[test]
    fn row_band_api_tiles_the_whole_layer() {
        let mut rng = Rng::new(11);
        let banks = random_banks(&mut rng, 4, 3, 3);
        let input = NdTensor::random(&[10, 9, 3], 13, -1.0, 1.0).to_fixed();
        let mut scratch = KernelScratch::new();
        let whole = conv2d_fx(&input, &banks, 1, false, 1, &mut scratch);
        let geom = ConvGeom::for_input(&input, &banks, 1);
        let mut tiled = FxTensor::zeros(&[geom.out_h(), geom.out_w(), 4]);
        scratch.pack_filters(&banks);
        let mut r = 0;
        while r < geom.out_h() {
            let r1 = (r + 3).min(geom.out_h());
            conv2d_fx_rows(&input, &banks, 1, false, r..r1, &mut scratch, &mut tiled);
            r = r1;
        }
        assert_eq!(whole, tiled);
    }

    #[test]
    fn scratch_reuse_across_layer_shapes_is_safe() {
        // Grow, shrink, grow again: the shared scratch must never leak one
        // layer's geometry into the next.
        let mut rng = Rng::new(17);
        let mut scratch = KernelScratch::new();
        for &(h, w, d, k) in &[(9usize, 9usize, 8usize, 4usize), (5, 5, 2, 3), (12, 7, 6, 8)] {
            let banks = random_banks(&mut rng, k, 3, d);
            let input = NdTensor::random(&[h, w, d], rng.next_u64(), -1.0, 1.0).to_fixed();
            let shared = conv2d_fx(&input, &banks, 1, true, 1, &mut scratch);
            let fresh = conv2d_fx(&input, &banks, 1, true, 1, &mut KernelScratch::new());
            assert_eq!(shared, fresh);
        }
    }

    #[test]
    fn forward_network_matches_naive_reference() {
        let net = paper_test_example();
        let weights = Weights::random(&net, 3);
        let input = NdTensor::random(&net.input.as_slice(), 4, -1.0, 1.0).to_fixed();
        let mut scratch = KernelScratch::new();
        let fast = forward_network_fx(&net, &weights, &input, 2, &mut scratch);
        let slow = naive::forward_network_fx_naive(&net, &weights, &input);
        assert_eq!(fast, slow);
    }

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
    }
}
