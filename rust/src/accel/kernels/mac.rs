//! The cache-blocked, depth-flattened MAC kernel.
//!
//! One im2col row (a depth-concatenated window) is a `patch = kernel²·d`
//! vector; the layer's filters form a `patch × k` matrix. The kernel is a
//! register-tiled GEMM specialized to the Q16.16 datapath: a 4×4 tile of
//! (output pixels × output filters) accumulates in `i64` registers while the
//! inner loop streams the *entire* patch — every input channel of the window
//! in one pass, the software image of the paper's depth-parallel MAC burst.
//!
//! Accumulation per (pixel, filter) walks the patch in ascending
//! `tap·d + c` order with saturating adds, exactly like
//! [`crate::accel::conv3d::ConvUnit::compute_pixel_into`] and the naive
//! oracle, so all three paths are bit-identical (see the module docs of
//! [`super`]).

use crate::accel::depth_concat::FilterBanks;
use crate::tensor::fixed::{Fx, MacAcc};

/// Register tile extents: MR output pixels × NR output filters.
const MR: usize = 4;
const NR: usize = 4;

/// Patch-major packed weights: `mat[p·k + f]` is filter `f`'s weight for
/// patch position `p = tap·d + c`. The repack (from the tap-major BRAM
/// layout of [`FilterBanks`]) costs one `patch·k` copy per layer and buys a
/// single unit-stride weight stream for the whole MAC loop.
#[derive(Debug, Default)]
pub struct PackedFilters {
    mat: Vec<Fx>,
    biases: Vec<Fx>,
    /// Patch length this matrix was packed for (`kernel²·d`).
    pub patch: usize,
    /// Output filters.
    pub k: usize,
}

impl PackedFilters {
    /// (Re)pack `banks` into the patch-major layout, reusing the allocation.
    pub fn pack(&mut self, banks: &FilterBanks) {
        let taps = banks.w * banks.w;
        let (d, k) = (banks.d, banks.k);
        self.patch = taps * d;
        self.k = k;
        self.mat.clear();
        self.mat.reserve(self.patch * k);
        for t in 0..taps {
            for c in 0..d {
                self.mat.extend_from_slice(banks.tap_channel_all_filters(t, c));
            }
        }
        self.biases.clear();
        self.biases.extend((0..k).map(|f| banks.bias(f)));
    }

    #[inline]
    fn row(&self, p: usize) -> &[Fx] {
        &self.mat[p * self.k..(p + 1) * self.k]
    }
}

/// Multiply a band of im2col rows by the packed filters: `col` holds
/// `n_px · patch` values, `out` receives `n_px · k` finished Q16.16 outputs
/// (bias, requantization, optional ReLU applied).
pub fn mac_band(col: &[Fx], packed: &PackedFilters, patch: usize, relu: bool, out: &mut [Fx]) {
    debug_assert_eq!(packed.patch, patch);
    let k = packed.k;
    assert_eq!(col.len() % patch, 0);
    let n_px = col.len() / patch;
    assert_eq!(out.len(), n_px * k);

    let mut i = 0;
    while i < n_px {
        let mi = (i + MR).min(n_px) - i;
        let mut j = 0;
        while j < k {
            let nj = (j + NR).min(k) - j;
            // 4×4 micro-kernel: accumulators live in registers across the
            // whole patch walk; `p` ascends so the add order matches the
            // hardware-mirroring paths exactly.
            let mut acc = [[0i64; NR]; MR];
            for p in 0..patch {
                let wrow = &packed.row(p)[j..j + nj];
                for (ii, arow) in acc.iter_mut().enumerate().take(mi) {
                    let x = col[(i + ii) * patch + p].0 as i64;
                    if x == 0 {
                        continue;
                    }
                    for (a, wv) in arow.iter_mut().zip(wrow) {
                        *a = a.saturating_add(x * wv.0 as i64);
                    }
                }
            }
            for (ii, arow) in acc.iter().enumerate().take(mi) {
                let out_row = &mut out[(i + ii) * k + j..(i + ii) * k + j + nj];
                for ((slot, &a), f) in out_row.iter_mut().zip(arow).zip(j..j + nj) {
                    let mut m = MacAcc(a);
                    m.add_bias(packed.biases[f]);
                    let v = m.finish();
                    *slot = if relu { v.relu() } else { v };
                }
            }
            j += nj;
        }
        i += mi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::NdTensor;
    use crate::util::prng::Rng;
    use crate::util::prop;

    fn random_banks(seed: u64, k: usize, w: usize, d: usize) -> FilterBanks {
        let mut rng = Rng::new(seed);
        let filt = NdTensor::random(&[k, w, w, d], rng.next_u64(), -0.5, 0.5);
        let bias = NdTensor::random(&[k], rng.next_u64(), -0.1, 0.1);
        FilterBanks::from_tensor(&filt, &bias)
    }

    #[test]
    fn packed_layout_matches_banks() {
        let banks = random_banks(1, 5, 3, 4);
        let mut p = PackedFilters::default();
        p.pack(&banks);
        assert_eq!(p.patch, 9 * 4);
        assert_eq!(p.k, 5);
        for t in 0..9 {
            for c in 0..4 {
                for f in 0..5 {
                    assert_eq!(p.row(t * 4 + c)[f], banks.tap(f, t)[c]);
                }
            }
        }
    }

    #[test]
    fn repack_reuses_cleanly_across_shapes() {
        let mut p = PackedFilters::default();
        p.pack(&random_banks(2, 8, 3, 6));
        p.pack(&random_banks(3, 2, 3, 1));
        assert_eq!(p.patch, 9);
        assert_eq!(p.k, 2);
        assert_eq!(p.mat.len(), 9 * 2);
        assert_eq!(p.biases.len(), 2);
    }

    /// Scalar MacAcc reference in the canonical accumulation order.
    fn reference(col: &[Fx], banks: &FilterBanks, patch: usize, relu: bool) -> Vec<Fx> {
        let (d, k) = (banks.d, banks.k);
        let n_px = col.len() / patch;
        let mut out = Vec::with_capacity(n_px * k);
        for px in 0..n_px {
            let row = &col[px * patch..(px + 1) * patch];
            for f in 0..k {
                let mut acc = MacAcc::new();
                for (p, x) in row.iter().enumerate() {
                    let (t, c) = (p / d, p % d);
                    acc.mac(*x, banks.tap(f, t)[c]);
                }
                acc.add_bias(banks.bias(f));
                let v = acc.finish();
                out.push(if relu { v.relu() } else { v });
            }
        }
        out
    }

    #[test]
    fn tile_edges_and_relu_match_reference() {
        prop::check_default(
            "mac-band-vs-macacc",
            |r: &mut Rng| {
                // Deliberately straddle the 4×4 tile: 1..10 pixels/filters.
                let n_px = r.range_usize(1, 10);
                let d = r.range_usize(1, 7);
                let k = r.range_usize(1, 10);
                (n_px, d, k, r.chance(0.5), r.next_u64())
            },
            |&(n_px, d, k, relu, seed)| {
                let banks = random_banks(seed, k, 3, d);
                let patch = 9 * d;
                let mut rng = Rng::new(seed ^ 0xABCD);
                let col: Vec<Fx> = (0..n_px * patch)
                    .map(|_| {
                        if rng.chance(0.3) {
                            Fx::ZERO // exercise the zero-skip
                        } else {
                            Fx::from_f32(rng.range_f32(-1.0, 1.0))
                        }
                    })
                    .collect();
                let mut packed = PackedFilters::default();
                packed.pack(&banks);
                let mut out = vec![Fx::ZERO; n_px * k];
                mac_band(&col, &packed, patch, relu, &mut out);
                let want = reference(&col, &banks, patch, relu);
                if out == want {
                    Ok(())
                } else {
                    Err("mac_band diverged from MacAcc reference".to_string())
                }
            },
        );
    }
}
