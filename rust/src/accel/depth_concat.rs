//! Depth concatenation (paper §III-B, Fig 4): input pixels and filter taps
//! are flattened along depth into single wide words, so all `d_g` channels
//! move and multiply together in one cycle.
//!
//! On the input side [`crate::tensor::FxTensor::pixel`] already yields the
//! depth-contiguous word; this module adds the filter-side flattening — the
//! paper instantiates w·w separate filter BRAMs, one per kernel tap, each
//! holding that tap's depth-concatenated values for all k filters in
//! sequence, so a whole 3-D filter is readable in one cycle.

use crate::tensor::fixed::Fx;
use crate::tensor::NdTensor;

/// Filter bank memory layout for one conv layer.
///
/// `banks[t]` is the BRAM for kernel tap `t` (row-major `t = ty*w + tx`);
/// its contents are `k` filters × `d` channels, filter-major:
/// `banks[t][f*d + c]` = weight of filter `f`, tap `t`, channel `c`.
#[derive(Debug, Clone)]
pub struct FilterBanks {
    pub w: usize,
    pub d: usize,
    pub k: usize,
    banks: Vec<Vec<Fx>>,
    /// Transposed copy of each bank — `trans[t][c*k + f]` — so the
    /// functional simulator can broadcast one window value across all k
    /// filters with unit stride (§Perf L3 iteration 3). Pure simulator
    /// implementation detail: the modeled hardware reads `banks` (Fig 4).
    trans: Vec<Vec<Fx>>,
    biases: Vec<Fx>,
}

impl FilterBanks {
    /// Flatten a `[k, w, w, d]` filter tensor + `[k]` biases.
    pub fn from_tensor(filters: &NdTensor, biases: &NdTensor) -> FilterBanks {
        let shape = filters.shape();
        assert_eq!(shape.len(), 4, "filters must be [k, w, w, d]");
        let (k, wh, ww, d) = (shape[0], shape[1], shape[2], shape[3]);
        assert_eq!(wh, ww, "square kernels only");
        assert_eq!(biases.shape(), &[k]);
        let mut banks = vec![Vec::with_capacity(k * d); wh * ww];
        for f in 0..k {
            for ty in 0..wh {
                for tx in 0..ww {
                    let bank = &mut banks[ty * ww + tx];
                    for c in 0..d {
                        bank.push(Fx::from_f32(filters.at4(f, ty, tx, c)));
                    }
                }
            }
        }
        let trans = banks
            .iter()
            .map(|bank| {
                let mut t = vec![Fx::ZERO; k * d];
                for f in 0..k {
                    for c in 0..d {
                        t[c * k + f] = bank[f * d + c];
                    }
                }
                t
            })
            .collect();
        FilterBanks {
            w: wh,
            d,
            k,
            banks,
            trans,
            biases: biases.data().iter().map(|&b| Fx::from_f32(b)).collect(),
        }
    }

    /// All k filters' weights for tap `t`, channel `c` — contiguous.
    #[inline]
    pub fn tap_channel_all_filters(&self, t: usize, c: usize) -> &[Fx] {
        &self.trans[t][c * self.k..(c + 1) * self.k]
    }

    /// The depth-concatenated word for filter `f`, tap `t` — all `d` channel
    /// weights, contiguous (one BRAM read in hardware).
    #[inline]
    pub fn tap(&self, f: usize, t: usize) -> &[Fx] {
        &self.banks[t][f * self.d..(f + 1) * self.d]
    }

    /// Same restricted to a depth group `[c0, c0+len)` — iterative
    /// decomposition reads only the group's slice.
    #[inline]
    pub fn tap_group(&self, f: usize, t: usize, c0: usize, len: usize) -> &[Fx] {
        &self.banks[t][f * self.d + c0..f * self.d + c0 + len]
    }

    #[inline]
    pub fn bias(&self, f: usize) -> Fx {
        self.biases[f]
    }

    /// Number of kernel taps (= number of filter BRAMs, `w*w`).
    pub fn n_banks(&self) -> usize {
        self.banks.len()
    }

    /// Words per bank (each word is a `d`-channel concatenation).
    pub fn words_per_bank(&self) -> usize {
        self.k
    }

    /// Bits per concatenated word at `word_bytes` per channel value.
    pub fn word_bits(&self, word_bytes: usize) -> usize {
        self.d * word_bytes * 8
    }

    /// Total weight bytes (what DDR must deliver for this layer).
    pub fn total_bytes(&self, word_bytes: usize) -> u64 {
        ((self.k * self.w * self.w * self.d + self.k) * word_bytes) as u64
    }
}

/// Split a depth-concatenated word into `groups` contiguous chunks of at most
/// `group_len` channels (paper Fig 4: the concatenated window "can be simply
/// split into independent windows which are parallelly sent to the
/// convolution block"; with iterative decomposition the split is per group).
pub fn split_groups(word: &[Fx], group_len: usize) -> Vec<&[Fx]> {
    assert!(group_len >= 1);
    word.chunks(group_len).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_filters(k: usize, w: usize, d: usize) -> (NdTensor, NdTensor) {
        // weight(f, ty, tx, c) = f*1000 + ty*100 + tx*10 + c (all exact in Q16.16)
        let mut filt = NdTensor::zeros(&[k, w, w, d]);
        for f in 0..k {
            for ty in 0..w {
                for tx in 0..w {
                    for c in 0..d {
                        filt.set(
                            &[f, ty, tx, c],
                            (f * 1000 + ty * 100 + tx * 10 + c) as f32,
                        );
                    }
                }
            }
        }
        let biases = NdTensor::from_vec(&[k], (0..k).map(|f| f as f32 * 0.5).collect());
        (filt, biases)
    }

    #[test]
    fn bank_count_is_w_squared() {
        let (f, b) = sample_filters(3, 3, 3);
        let banks = FilterBanks::from_tensor(&f, &b);
        assert_eq!(banks.n_banks(), 9);
        assert_eq!(banks.words_per_bank(), 3);
    }

    #[test]
    fn tap_layout_matches_source() {
        let (f, b) = sample_filters(4, 3, 5);
        let banks = FilterBanks::from_tensor(&f, &b);
        for filt in 0..4 {
            for ty in 0..3 {
                for tx in 0..3 {
                    let tap = banks.tap(filt, ty * 3 + tx);
                    assert_eq!(tap.len(), 5);
                    for c in 0..5 {
                        assert_eq!(
                            tap[c].to_f32(),
                            (filt * 1000 + ty * 100 + tx * 10 + c) as f32,
                            "mismatch f={filt} t=({ty},{tx}) c={c}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn tap_group_slices_depth() {
        let (f, b) = sample_filters(2, 3, 8);
        let banks = FilterBanks::from_tensor(&f, &b);
        let g = banks.tap_group(1, 4, 4, 4); // filter 1, center tap, channels 4..8
        assert_eq!(g.len(), 4);
        assert_eq!(g[0].to_f32(), (1000 + 100 + 10 + 4) as f32);
    }

    #[test]
    fn biases_kept() {
        let (f, b) = sample_filters(3, 3, 2);
        let banks = FilterBanks::from_tensor(&f, &b);
        assert_eq!(banks.bias(2).to_f32(), 1.0);
    }

    #[test]
    fn sizes() {
        let (f, b) = sample_filters(64, 3, 3);
        let banks = FilterBanks::from_tensor(&f, &b);
        assert_eq!(banks.word_bits(4), 96); // paper's example: 3×32 = 96-bit word
        assert_eq!(banks.total_bytes(4), (64 * 9 * 3 + 64) * 4);
    }

    #[test]
    fn split_groups_chunks() {
        let word: Vec<Fx> = (0..10).map(|i| Fx::from_f32(i as f32)).collect();
        let gs = split_groups(&word, 4);
        assert_eq!(gs.len(), 3);
        assert_eq!(gs[0].len(), 4);
        assert_eq!(gs[2].len(), 2);
        assert_eq!(gs[2][0].to_f32(), 8.0);
    }

    #[test]
    #[should_panic(expected = "square kernels")]
    fn rejects_non_square() {
        let f = NdTensor::zeros(&[2, 3, 5, 2]);
        let b = NdTensor::zeros(&[2]);
        FilterBanks::from_tensor(&f, &b);
    }
}
