//! The DeCoILFNet streaming engine: an element-level timestamp simulator
//! (exact cycle accounting under the paper's pipeline semantics) plus a
//! bit-exact functional forward pass in the Q16.16 datapath.
//!
//! ## Timing semantics (paper §III)
//!
//! Per fused group, every layer is a streaming stage:
//!  * input pixels (depth-concatenated words) arrive row-major with
//!    timestamps — from DDR for the group's first layer, from the previous
//!    stage otherwise;
//!  * a conv layer forms one window per cycle via its line buffer
//!    ([`WindowSchedule`]), holds the window for `k·f_g` cycles while the k
//!    filters (× f_g serial depth groups) stream through the multiplier/
//!    adder-tree pipeline (latency `9·(1+2·ceil(log2 w)+ceil(log2 d_g))`),
//!    and emits the completed depth-concatenated output pixel;
//!  * the line buffer holds `win` rows — a producer stalls when it would
//!    overwrite a pixel still needed (backpressure propagates upstream
//!    through these capacity gates);
//!  * pooling consumes the conv stream at II=1 and emits a pooled row after
//!    its second input row;
//!  * group boundary volumes cross the serializing DDR channel; weights are
//!    loaded at group start (reported separately — see `weight_load_cycles`).

use crate::config::{AccelConfig, Layer, Network};
use crate::fpga::ddr::{DdrChannel, Dir};
use crate::fpga::line_buffer::WindowSchedule;
use crate::tensor::{FxTensor, NdTensor};

use super::conv3d::ConvUnit;
use super::depth_concat::FilterBanks;
use super::fusion::FusionPlan;
use super::pool::PoolUnit;

/// Per-layer weights for a network's conv layers (in layer order).
#[derive(Debug, Clone)]
pub struct Weights {
    /// One entry per layer; `None` for pooling layers.
    pub banks: Vec<Option<FilterBanks>>,
}

impl Weights {
    /// Deterministic random weights (He-style scale) for testing/benching.
    pub fn random(net: &Network, seed: u64) -> Weights {
        let shapes = net.shapes();
        let mut rng = crate::util::prng::Rng::new(seed);
        let mut banks = Vec::new();
        for (i, layer) in net.layers.iter().enumerate() {
            match layer {
                Layer::Conv { kernel, filters, .. } => {
                    let d = shapes[i].d;
                    let fan_in = (kernel * kernel * d) as f32;
                    let scale = (2.0 / fan_in).sqrt();
                    let filt = NdTensor::random(
                        &[*filters, *kernel, *kernel, d],
                        rng.next_u64(),
                        -scale,
                        scale,
                    );
                    let bias = NdTensor::random(&[*filters], rng.next_u64(), -0.01, 0.01);
                    banks.push(Some(FilterBanks::from_tensor(&filt, &bias)));
                }
                Layer::MaxPool { .. } => banks.push(None),
            }
        }
        Weights { banks }
    }

    /// Build from raw `[k,w,w,d]` filter + `[k]` bias tensors per conv layer
    /// (layer order, pools skipped) — the artifact-loading path.
    pub fn from_tensors(net: &Network, tensors: Vec<(NdTensor, NdTensor)>) -> Weights {
        let mut it = tensors.into_iter();
        let banks = net
            .layers
            .iter()
            .map(|l| match l {
                Layer::Conv { .. } => {
                    let (f, b) = it.next().expect("missing conv weights");
                    Some(FilterBanks::from_tensor(&f, &b))
                }
                Layer::MaxPool { .. } => None,
            })
            .collect();
        assert!(it.next().is_none(), "extra weight tensors");
        Weights { banks }
    }

    /// Total weight bytes for a set of layers (word_bytes per value).
    pub fn bytes_for_layers(&self, layers: std::ops::Range<usize>, word_bytes: usize) -> u64 {
        layers
            .filter_map(|i| self.banks[i].as_ref())
            .map(|b| b.total_bytes(word_bytes))
            .sum()
    }

    /// Weight bytes of every layer (0 for pools), derived once — callers
    /// that price layer subsets inside loops (the migration biller, the
    /// fleet costing context) index this instead of re-walking the banks
    /// per query.
    pub fn per_layer_bytes(&self, word_bytes: usize) -> Vec<u64> {
        self.banks
            .iter()
            .map(|b| b.as_ref().map_or(0, |b| b.total_bytes(word_bytes)))
            .collect()
    }
}

/// Timing report for one layer within a simulated run.
#[derive(Debug, Clone)]
pub struct LayerTiming {
    pub name: String,
    /// Cycle the layer's first output pixel is available.
    pub first_out: u64,
    /// Cycle the layer's last output pixel is available.
    pub last_out: u64,
    /// Cycles between successive output pixels in steady state (k·f_g for
    /// conv; input-limited for pool).
    pub rate: u64,
    /// Output pixels produced.
    pub out_pixels: u64,
}

/// Timing report for one fused group.
#[derive(Debug, Clone)]
pub struct GroupTiming {
    pub layers: std::ops::Range<usize>,
    pub start: u64,
    pub end: u64,
    pub weight_load_cycles: u64,
}

/// Full simulation report.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// End-to-end cycles, excluding weight loading (the paper's accounting:
    /// weights are resident before streaming starts; serving amortizes the
    /// load across frames).
    pub total_cycles: u64,
    /// Cycles spent pre-loading weights at group starts (reported separately;
    /// `cold_cycles()` adds them).
    pub weight_load_cycles: u64,
    pub ddr_read_bytes: u64,
    pub ddr_write_bytes: u64,
    pub per_layer: Vec<LayerTiming>,
    pub per_group: Vec<GroupTiming>,
}

impl SimReport {
    pub fn cold_cycles(&self) -> u64 {
        self.total_cycles + self.weight_load_cycles
    }

    pub fn total_mb(&self) -> f64 {
        (self.ddr_read_bytes + self.ddr_write_bytes) as f64 / (1024.0 * 1024.0)
    }

    pub fn ms_at(&self, freq_mhz: f64) -> f64 {
        self.total_cycles as f64 / (freq_mhz * 1e3)
    }
}

/// The DeCoILFNet engine.
#[derive(Debug, Clone)]
pub struct Engine {
    pub cfg: AccelConfig,
}

impl Engine {
    pub fn new(cfg: AccelConfig) -> Engine {
        Engine { cfg }
    }

    // ------------------------------------------------------------------
    // Timing simulation
    // ------------------------------------------------------------------

    /// Simulate one input frame through the network under `plan`.
    /// Timing only — no data is computed. O(total pixels) per layer.
    pub fn simulate(&self, net: &Network, weights: &Weights, plan: &FusionPlan) -> SimReport {
        assert_eq!(plan.n_layers(), net.layers.len(), "plan/network mismatch");
        assert!(plan.is_valid_partition());
        let shapes = net.shapes();
        let wb = self.cfg.platform.word_bytes;
        let mut ddr = DdrChannel::new(self.cfg.platform.ddr_bytes_per_cycle);
        let mut per_layer = Vec::new();
        let mut per_group = Vec::new();
        let mut weight_load_total = 0u64;
        let mut t_group_start = 0u64;

        for group in plan.groups() {
            let in_shape = shapes[group.start];

            // Weights for the whole group load before streaming (reported
            // separately from the streaming cycles — see module docs).
            let wbytes = weights.bytes_for_layers(group.clone(), wb);
            let weight_load = ddr.cycles_for(wbytes);
            ddr.account_only(&format!("weights[g{}..{}]", group.start, group.end), Dir::Read, wbytes);
            weight_load_total += weight_load;

            // Group input streamed from DDR, row bursts on the channel. One
            // label per group — building a fresh `format!` string per row
            // dominated this loop's profile (shape inference and labels are
            // now derived once per group, not per row).
            let mut avail: Vec<u64> =
                Vec::with_capacity(in_shape.h * in_shape.w);
            let row_bytes = (in_shape.w * in_shape.d * wb) as u64;
            let in_label = format!("in[g{}] rows", group.start);
            for _ in 0..in_shape.h {
                let end = ddr.transfer(&in_label, Dir::Read, row_bytes, t_group_start);
                for _ in 0..in_shape.w {
                    avail.push(end);
                }
            }

            // Stream through the group's layers.
            for li in group.clone() {
                let in_sh = shapes[li];
                let timing = match &net.layers[li] {
                    Layer::Conv {
                        name,
                        kernel,
                        filters,
                        padding,
                        ..
                    } => {
                        let unit = ConvUnit::for_layer(&self.cfg, *kernel, in_sh.d, *filters);
                        let (next, t) = conv_layer_timing(
                            name,
                            &avail,
                            WindowSchedule::new(in_sh.h, in_sh.w, *kernel, *padding),
                            &unit,
                        );
                        avail = next;
                        t
                    }
                    Layer::MaxPool { name, window, stride } => {
                        let (next, t) = pool_layer_timing(
                            name,
                            &avail,
                            in_sh.h,
                            in_sh.w,
                            PoolUnit::new(*window, *stride),
                        );
                        avail = next;
                        t
                    }
                };
                per_layer.push(timing);
            }

            // Group output written back to DDR in row bursts.
            let out_shape = shapes[group.end];
            let out_row_bytes = (out_shape.w * out_shape.d * wb) as u64;
            let out_label = format!("out[g{}] rows", group.start);
            let mut end = t_group_start;
            for r in 0..out_shape.h {
                let row_last = avail[(r + 1) * out_shape.w - 1];
                end = ddr.transfer(&out_label, Dir::Write, out_row_bytes, row_last);
            }
            per_group.push(GroupTiming {
                layers: group.clone(),
                start: t_group_start,
                end,
                weight_load_cycles: weight_load,
            });
            t_group_start = end;
        }

        SimReport {
            total_cycles: t_group_start,
            weight_load_cycles: weight_load_total,
            ddr_read_bytes: ddr.read_bytes,
            ddr_write_bytes: ddr.write_bytes,
            per_layer,
            per_group,
        }
    }

    /// Multi-frame steady-state throughput: `n_frames` inputs stream
    /// back-to-back through the fused pipeline. Weights load once; each
    /// frame's fill latency overlaps the previous frame's drain, so
    /// throughput approaches `1 / bottleneck-work` — the serving-side
    /// number the coordinator's batcher exploits.
    ///
    /// Returns (total cycles, cycles per frame at steady state).
    pub fn simulate_stream(
        &self,
        net: &Network,
        weights: &Weights,
        plan: &FusionPlan,
        n_frames: usize,
    ) -> (u64, f64) {
        assert!(n_frames >= 1);
        let single = self.simulate(net, weights, plan);
        if n_frames == 1 {
            return (single.total_cycles, single.total_cycles as f64);
        }
        // Frame k may start streaming as soon as the first layer's line
        // buffer has drained frame k-1 — i.e. one frame per bottleneck
        // interval. Per-layer steady-state work (rate × pixels) is derived
        // once — shapes and compute units used to be re-inferred in two
        // separate passes over the plan.
        let shapes = net.shapes();
        let work: Vec<u64> = net
            .layers
            .iter()
            .enumerate()
            .map(|(li, layer)| {
                let out = shapes[li + 1];
                match layer {
                    Layer::Conv { kernel, filters, .. } => {
                        let unit =
                            ConvUnit::for_layer(&self.cfg, *kernel, shapes[li].d, *filters);
                        (out.h * out.w) as u64 * unit.cycles_per_output_pixel()
                    }
                    Layer::MaxPool { .. } => (out.h * out.w) as u64,
                }
            })
            .collect();
        // Groups execute serially per frame, so the per-frame interval is
        // the sum over groups of each group's bottleneck stage.
        let interval: u64 = plan
            .groups()
            .into_iter()
            .map(|g| work[g].iter().copied().max().unwrap_or(0))
            .sum();
        let total = single.total_cycles + interval * (n_frames as u64 - 1);
        (total, interval as f64)
    }

    // ------------------------------------------------------------------
    // Functional forward (bit-exact datapath)
    // ------------------------------------------------------------------

    /// Run the network functionally in the Q16.16 datapath through the
    /// shared depth-flattened kernels ([`crate::accel::kernels`]): one
    /// im2col scratch reused across every layer, row bands fanned over
    /// scoped threads. Fusion does not change values (only movement), so
    /// this is plan-independent; the bit-exact naive oracle lives in
    /// [`crate::accel::kernels::naive`].
    pub fn forward_fx(&self, net: &Network, weights: &Weights, input: &NdTensor) -> FxTensor {
        assert_eq!(input.shape(), &net.input.as_slice());
        let mut scratch = super::kernels::KernelScratch::new();
        super::kernels::forward_network_fx(
            net,
            weights,
            &input.to_fixed(),
            super::kernels::default_threads(),
            &mut scratch,
        )
    }

    /// One layer of the functional pass (exposed for layer-by-layer
    /// verification against the JAX reference). Same kernel path as
    /// [`Engine::forward_fx`], with a per-call scratch.
    pub fn forward_layer_fx(
        &self,
        net: &Network,
        weights: &Weights,
        li: usize,
        input: &FxTensor,
    ) -> FxTensor {
        let in_sh = net.shape_before(li);
        assert_eq!(input.shape(), &in_sh.as_slice());
        match &net.layers[li] {
            Layer::Conv { padding, relu, .. } => {
                let banks = weights.banks[li].as_ref().expect("conv layer needs weights");
                let mut scratch = super::kernels::KernelScratch::new();
                super::kernels::conv2d_fx(
                    input,
                    banks,
                    *padding,
                    *relu,
                    super::kernels::default_threads(),
                    &mut scratch,
                )
            }
            Layer::MaxPool { window, stride, .. } => {
                PoolUnit::new(*window, *stride).forward(input)
            }
        }
    }
}

/// Timestamp propagation through one conv layer (see module docs).
/// Returns (output pixel avail times, layer timing).
fn conv_layer_timing(
    name: &str,
    avail: &[u64],
    sched: WindowSchedule,
    unit: &ConvUnit,
) -> (Vec<u64>, LayerTiming) {
    let rate = unit.cycles_per_output_pixel();
    let latency = unit.stage().latency;
    let n_px = sched.n_pixels();
    let n_win = sched.n_windows();
    let cap = sched.capacity_pixels();
    debug_assert_eq!(avail.len(), n_px);

    // Filled strictly in order — with_capacity + push avoids the memset that
    // dominated the profile (§Perf L3 iteration 1).
    let mut pixel_write: Vec<u64> = Vec::with_capacity(n_px);
    let mut issue: Vec<u64> = Vec::with_capacity(n_win);
    let mut out_avail: Vec<u64> = Vec::with_capacity(n_win);
    let ow = sched.out_w();
    let w_img = sched.w;
    let mut cursor = 0usize; // next window to issue
    let mut last_issue = 0u64;
    let mut primed = false;
    // Incremental coordinates (divisions in the hot loop cost ~15% — §Perf
    // L3 iteration 2): (ir, ic) for pixel i, (jr, jc) for pixel i-cap,
    // (wr, wc) for the window cursor.
    let (mut ir, mut ic) = (0usize, 0usize);
    let (mut jr, mut jc) = (0usize, 0usize);
    let (mut wr, mut wc) = (0usize, 0usize);
    // Trigger of the cursor window, updated when the cursor moves.
    let mut cursor_trigger = if n_win > 0 {
        sched.trigger_pixel(0, 0)
    } else {
        usize::MAX
    };

    for i in 0..n_px {
        // Ring-buffer backpressure: pixel i reuses the slot of pixel i-cap,
        // which must have been read by its last consuming window. That
        // window's trigger precedes i (see line_buffer::ring_reuse_is_safe),
        // so its issue time is already known.
        let mut t = avail[i];
        if i >= cap {
            let freeing = sched.last_window_of_pixel(jr, jc);
            debug_assert!(freeing < cursor, "freeing window not yet issued");
            t = t.max(issue[freeing]);
            jc += 1;
            if jc == w_img {
                jc = 0;
                jr += 1;
            }
        }
        pixel_write.push(t);

        // Issue every window whose trigger pixel is now present.
        while cursor < n_win && cursor_trigger <= i {
            let ready = pixel_write[cursor_trigger] + 1;
            let t_issue = if primed {
                ready.max(last_issue + rate)
            } else {
                primed = true;
                ready
            };
            last_issue = t_issue;
            issue.push(t_issue);
            // The depth-concatenated output pixel completes with its last
            // filter result, `rate-1` cycles after issue plus the pipeline
            // latency, and is written downstream the next cycle.
            out_avail.push(t_issue + (rate - 1) + latency + 1);
            cursor += 1;
            wc += 1;
            if wc == ow {
                wc = 0;
                wr += 1;
            }
            if cursor < n_win {
                cursor_trigger = sched.trigger_pixel(wr, wc);
            }
        }
        ic += 1;
        if ic == w_img {
            ic = 0;
            ir += 1;
        }
    }
    let _ = (ir, ic);
    debug_assert_eq!(cursor, n_win, "not all windows issued");

    let timing = LayerTiming {
        name: name.to_string(),
        first_out: out_avail.first().copied().unwrap_or(0),
        last_out: out_avail.last().copied().unwrap_or(0),
        rate,
        out_pixels: n_win as u64,
    };
    (out_avail, timing)
}

/// Timestamp propagation through a pooling layer.
fn pool_layer_timing(
    name: &str,
    avail: &[u64],
    h: usize,
    w: usize,
    unit: PoolUnit,
) -> (Vec<u64>, LayerTiming) {
    let (oh, ow) = (unit.out_extent(h), unit.out_extent(w));
    let mut out = Vec::with_capacity(oh * ow);
    let mut last_emit: Option<u64> = None;
    for oy in 0..oh {
        for ox in 0..ow {
            // Ready when the bottom-right contributor arrives (+1 compare).
            let ly = oy * unit.stride + unit.window - 1;
            let lx = ox * unit.stride + unit.window - 1;
            let ready = avail[ly * w + lx] + unit.stage().latency;
            let t = match last_emit {
                None => ready,
                Some(prev) => ready.max(prev + 1),
            };
            last_emit = Some(t);
            out.push(t);
        }
    }
    let timing = LayerTiming {
        name: name.to_string(),
        first_out: out.first().copied().unwrap_or(0),
        last_out: out.last().copied().unwrap_or(0),
        rate: 1,
        out_pixels: (oh * ow) as u64,
    };
    (out, timing)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{paper_test_example, tiny_vgg, vgg16_prefix, AccelConfig};

    fn engine() -> Engine {
        Engine::new(AccelConfig::paper_default())
    }

    #[test]
    fn conv1_1_cycles_match_paper() {
        // Paper Table II: conv1_1 alone takes 26.76 ms at 120 MHz =
        // 3,211,264 cycles = 224·224 output pixels × 64 filters — the
        // filter-serial rate dominates everything else. Our simulator must
        // land within a fraction of a percent (fill + drain only).
        let net = {
            let full = vgg16_prefix();
            Network {
                name: "conv1_1".into(),
                input: full.input,
                layers: vec![full.layers[0].clone()],
            }
        };
        let w = Weights::random(&net, 1);
        let rep = engine().simulate(&net, &w, &FusionPlan::fully_fused(1));
        let ideal = 224 * 224 * 64u64;
        assert!(
            rep.total_cycles >= ideal,
            "cannot beat the filter-serial bound"
        );
        let overhead = rep.total_cycles as f64 / ideal as f64;
        assert!(
            overhead < 1.02,
            "fill/drain overhead too large: {} vs {ideal}",
            rep.total_cycles
        );
        let ms = rep.ms_at(120.0);
        assert!((ms - 26.76).abs() < 0.6, "got {ms} ms, paper says 26.76");
    }

    #[test]
    fn fused_second_conv_adds_only_fill_latency() {
        // Paper Table II: conv1_1→conv1_2 goes 26.76 → 27.01 ms: the fused
        // second conv adds ~0.25 ms (line-buffer fill at the intermediate
        // rate), not its own 26.76 ms of work.
        let full = vgg16_prefix();
        let net1 = Network {
            name: "p1".into(),
            input: full.input,
            layers: full.layers[..1].to_vec(),
        };
        let net2 = Network {
            name: "p2".into(),
            input: full.input,
            layers: full.layers[..2].to_vec(),
        };
        let e = engine();
        let r1 = e
            .simulate(&net1, &Weights::random(&net1, 1), &FusionPlan::fully_fused(1))
            .total_cycles;
        let r2 = e
            .simulate(&net2, &Weights::random(&net2, 1), &FusionPlan::fully_fused(2))
            .total_cycles;
        let delta_ms = (r2 - r1) as f64 / 120e3;
        assert!(
            delta_ms < 1.0,
            "fused conv1_2 should add ≪ its standalone time, added {delta_ms} ms"
        );
        assert!(r2 > r1, "adding a layer cannot reduce cycles");
    }

    #[test]
    fn unfused_pays_full_serialization() {
        // Unfused, the same two layers run back-to-back: total ≈ sum of
        // standalone times + DDR roundtrip of the intermediate volume.
        let full = vgg16_prefix();
        let net2 = Network {
            name: "p2".into(),
            input: full.input,
            layers: full.layers[..2].to_vec(),
        };
        let e = engine();
        let w = Weights::random(&net2, 1);
        let fused = e.simulate(&net2, &w, &FusionPlan::fully_fused(2));
        let unfused = e.simulate(&net2, &w, &FusionPlan::unfused(2));
        assert!(
            unfused.total_cycles as f64 > 1.8 * fused.total_cycles as f64,
            "unfused {} vs fused {}",
            unfused.total_cycles,
            fused.total_cycles
        );
        // And it moves the 224·224·64 intermediate through DDR twice.
        let inter_bytes = (224 * 224 * 64 * 4) as u64;
        assert!(unfused.ddr_read_bytes >= fused.ddr_read_bytes + inter_bytes);
        assert!(unfused.ddr_write_bytes >= fused.ddr_write_bytes + inter_bytes);
    }

    #[test]
    fn fusion_reduces_traffic_not_values() {
        let net = paper_test_example();
        let w = Weights::random(&net, 2);
        let e = engine();
        let fused = e.simulate(&net, &w, &FusionPlan::fully_fused(3));
        let unfused = e.simulate(&net, &w, &FusionPlan::unfused(3));
        assert!(fused.total_mb() < unfused.total_mb());
        // weights counted identically in both
        let wb: u64 = w.bytes_for_layers(0..3, 4);
        assert!(fused.ddr_read_bytes >= wb);
    }

    #[test]
    fn timing_monotone_through_layers() {
        let net = tiny_vgg();
        let w = Weights::random(&net, 3);
        let rep = engine().simulate(&net, &w, &FusionPlan::fully_fused(7));
        for pair in rep.per_layer.windows(2) {
            assert!(
                pair[1].last_out >= pair[0].first_out,
                "downstream cannot finish before upstream starts"
            );
        }
        for lt in &rep.per_layer {
            assert!(lt.last_out >= lt.first_out);
            assert!(lt.out_pixels > 0);
        }
    }

    #[test]
    fn functional_forward_shapes() {
        let net = tiny_vgg();
        let w = Weights::random(&net, 4);
        let input = NdTensor::random(&net.input.as_slice(), 9, -1.0, 1.0);
        let out = engine().forward_fx(&net, &w, &input);
        let expect = net.shape_after(net.layers.len() - 1);
        assert_eq!(out.shape(), &expect.as_slice());
    }

    #[test]
    fn functional_forward_is_plan_independent_and_deterministic() {
        let net = paper_test_example();
        let w = Weights::random(&net, 5);
        let input = NdTensor::random(&net.input.as_slice(), 11, -1.0, 1.0);
        let e = engine();
        let a = e.forward_fx(&net, &w, &input);
        let b = e.forward_fx(&net, &w, &input);
        assert_eq!(a, b);
    }

    #[test]
    fn relu_layers_produce_nonnegative() {
        let net = paper_test_example();
        let w = Weights::random(&net, 6);
        let input = NdTensor::random(&net.input.as_slice(), 13, -1.0, 1.0);
        let out = engine().forward_fx(&net, &w, &input);
        assert!(out.data().iter().all(|v| v.to_f32() >= 0.0));
    }

    #[test]
    fn weight_load_reported_separately() {
        let net = paper_test_example();
        let w = Weights::random(&net, 7);
        let rep = engine().simulate(&net, &w, &FusionPlan::fully_fused(3));
        assert!(rep.weight_load_cycles > 0);
        assert_eq!(rep.cold_cycles(), rep.total_cycles + rep.weight_load_cycles);
    }

    #[test]
    fn streaming_amortizes_fill_latency() {
        let net = vgg16_prefix();
        let w = Weights::random(&net, 9);
        let e = engine();
        let plan = FusionPlan::fully_fused(7);
        let (one, _) = e.simulate_stream(&net, &w, &plan, 1);
        let (ten, interval) = e.simulate_stream(&net, &w, &plan, 10);
        assert!(ten > one);
        // Steady-state interval is the bottleneck stage (3.21M cycles),
        // below the single-frame latency (fills + drain included).
        assert!(interval < one as f64);
        assert!((interval - 3_211_264.0).abs() / 3_211_264.0 < 0.01);
        // 10 frames ≈ latency + 9 intervals.
        assert_eq!(ten, one + 9 * interval as u64);
    }

    #[test]
    fn streaming_unfused_sums_group_bottlenecks() {
        let net = tiny_vgg();
        let w = Weights::random(&net, 10);
        let e = engine();
        let (_, fused_int) = e.simulate_stream(&net, &w, &FusionPlan::fully_fused(7), 8);
        let (_, unfused_int) = e.simulate_stream(&net, &w, &FusionPlan::unfused(7), 8);
        assert!(
            unfused_int > fused_int,
            "serialized groups must lower throughput: {unfused_int} vs {fused_int}"
        );
    }

    #[test]
    fn group_timings_tile_the_run() {
        let net = tiny_vgg();
        let w = Weights::random(&net, 8);
        let plan = FusionPlan::from_group_sizes(7, &[3, 2, 2]).unwrap();
        let rep = engine().simulate(&net, &w, &plan);
        assert_eq!(rep.per_group.len(), 3);
        assert_eq!(rep.per_group[0].start, 0);
        for pair in rep.per_group.windows(2) {
            assert_eq!(pair[1].start, pair[0].end, "groups must be contiguous");
        }
        assert_eq!(rep.per_group.last().unwrap().end, rep.total_cycles);
    }
}
