//! Max-pooling with an intermediate pool line buffer (paper §III-D).
//!
//! Convolution outputs are redirected into a pool row buffer at the current
//! output column address; at even steps the address advances, at odd steps
//! the stored value is replaced by the max of old and new. After two input
//! rows, a pooled row streams out. Depth-concatenated pixels pool laneswise.

use crate::fpga::pipeline::Stage;
use crate::tensor::fixed::Fx;
use crate::tensor::FxTensor;

/// Pooling unit configuration (the paper uses 2×2 stride 2 throughout).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolUnit {
    pub window: usize,
    pub stride: usize,
}

impl PoolUnit {
    pub fn new(window: usize, stride: usize) -> PoolUnit {
        assert!(window >= 1 && stride >= 1);
        PoolUnit { window, stride }
    }

    /// Timing: the comparator pipeline is shallow; one cycle per update,
    /// II = 1 against the incoming conv stream.
    pub fn stage(&self) -> Stage {
        Stage::pipelined(1)
    }

    pub fn out_extent(&self, extent: usize) -> usize {
        (extent - self.window) / self.stride + 1
    }

    /// Functional pooling of a whole `[h, w, d]` fixed-point volume —
    /// streaming semantics (running max in a row buffer), which for max-pool
    /// equals the gather-then-max reference exactly; tests assert that.
    pub fn forward(&self, input: &FxTensor) -> FxTensor {
        let (h, w, d) = (input.shape()[0], input.shape()[1], input.shape()[2]);
        let (oh, ow) = (self.out_extent(h), self.out_extent(w));
        let mut out = FxTensor::zeros(&[oh, ow, d]);
        // Row buffer holds one pooled row of ow × d running maxima.
        let mut row_buf: Vec<Fx> = vec![Fx::MIN; ow * d];
        for y in 0..h {
            let within = (y % self.stride) < self.window && y / self.stride < oh;
            let fresh_row = y % self.stride == 0;
            if fresh_row {
                row_buf.fill(Fx::MIN);
            }
            for x in 0..w {
                let ox = x / self.stride;
                if ox >= ow || (x % self.stride) >= self.window || !within {
                    continue;
                }
                for c in 0..d {
                    let old = row_buf[ox * d + c];
                    row_buf[ox * d + c] = old.max(input.at3(y, x, c));
                }
            }
            // Row completes the pooled row on the window's last line.
            if within && (y % self.stride) == self.window - 1 {
                let oy = y / self.stride;
                for ox in 0..ow {
                    for c in 0..d {
                        out.set3(oy, ox, c, row_buf[ox * d + c]);
                    }
                }
            }
        }
        out
    }

    /// Pool-buffer capacity in depth-concatenated words: one pooled row.
    pub fn buffer_words(&self, in_w: usize) -> usize {
        self.out_extent(in_w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::NdTensor;
    use crate::util::prng::Rng;
    use crate::util::prop;

    /// Direct gather reference.
    fn ref_pool(input: &FxTensor, window: usize, stride: usize) -> FxTensor {
        let (h, w, d) = (input.shape()[0], input.shape()[1], input.shape()[2]);
        let (oh, ow) = ((h - window) / stride + 1, (w - window) / stride + 1);
        let mut out = FxTensor::zeros(&[oh, ow, d]);
        for oy in 0..oh {
            for ox in 0..ow {
                for c in 0..d {
                    let mut m = Fx::MIN;
                    for dy in 0..window {
                        for dx in 0..window {
                            m = m.max(input.at3(oy * stride + dy, ox * stride + dx, c));
                        }
                    }
                    out.set3(oy, ox, c, m);
                }
            }
        }
        out
    }

    fn random_volume(seed: u64, h: usize, w: usize, d: usize) -> FxTensor {
        NdTensor::random(&[h, w, d], seed, -4.0, 4.0).to_fixed()
    }

    #[test]
    fn pool_2x2_known_values() {
        let data = vec![
            1.0, 5.0, 2.0, 0.0, //
            3.0, 4.0, 8.0, 1.0, //
            0.5, 0.25, 1.5, 2.5, //
            0.75, 0.1, 3.5, 0.2,
        ];
        let t = NdTensor::from_vec(&[4, 4, 1], data).to_fixed();
        let p = PoolUnit::new(2, 2).forward(&t);
        assert_eq!(p.shape(), &[2, 2, 1]);
        let vals: Vec<f32> = p.data().iter().map(|v| v.to_f32()).collect();
        assert_eq!(vals, vec![5.0, 8.0, 0.75, 3.5]);
    }

    #[test]
    fn streaming_equals_gather_property() {
        prop::check_default(
            "pool-stream-vs-gather",
            |r: &mut Rng| {
                let h = r.range_usize(2, 11);
                let w = r.range_usize(2, 11);
                let d = r.range_usize(1, 5);
                (h, w, d, r.next_u64())
            },
            |&(h, w, d, seed)| {
                let t = random_volume(seed, h, w, d);
                let got = PoolUnit::new(2, 2).forward(&t);
                let want = ref_pool(&t, 2, 2);
                if got == want {
                    Ok(())
                } else {
                    Err(format!("mismatch at {h}x{w}x{d}"))
                }
            },
        );
    }

    #[test]
    fn odd_extents_drop_tail() {
        let t = random_volume(3, 5, 7, 2);
        let p = PoolUnit::new(2, 2).forward(&t);
        assert_eq!(p.shape(), &[2, 3, 2]);
        assert_eq!(PoolUnit::new(2, 2).forward(&t), ref_pool(&t, 2, 2));
    }

    #[test]
    fn vgg_shapes() {
        let u = PoolUnit::new(2, 2);
        assert_eq!(u.out_extent(224), 112);
        assert_eq!(u.out_extent(112), 56);
        assert_eq!(u.buffer_words(224), 112);
    }

    #[test]
    fn stage_is_cheap() {
        let s = PoolUnit::new(2, 2).stage();
        assert_eq!(s.ii, 1);
        assert!(s.latency <= 2);
    }
}
