//! DeCoILFNet accelerator model: depth concatenation, the pipelined 3-D
//! convolution unit, pooling, inter-layer fusion plans, the streaming cycle
//! engine, and the closed-form latency model.
pub mod conv3d;
pub mod depth_concat;
pub mod engine;
pub mod fusion;
pub mod kernels;
pub mod latency;
pub mod pool;
pub mod trace;

pub use engine::{Engine, SimReport, Weights};
pub use fusion::FusionPlan;
