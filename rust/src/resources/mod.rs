//! Structural FPGA resource model (paper Tables I & IV, Fig 7).
//!
//! Resource usage of the DeCoILFNet architecture is structural — it follows
//! directly from the module inventory, the same way Vivado counts inferred
//! primitives:
//!
//! * **DSP**: one DSP48 per multiplier lane, `w·w·d_par` lanes per fused
//!   conv layer (the paper's "DSPs only for multipliers"). Table I's 605 for
//!   conv1_1+conv1_2 fused is exactly 27 + 576 lanes + 2 control DSPs.
//! * **BRAM**: line-buffer rows, filter banks and pool row buffers, each a
//!   wide word memory mapped through [`crate::fpga::bram`]'s Xilinx configs.
//! * **LUT**: the adder trees (the paper's "LUTs for adders"), the window
//!   register muxing/padding logic, and per-layer control.
//! * **FF**: pipeline registers in multipliers/adder trees plus the window
//!   register chains.
//!
//! LUT/FF constants are calibrated once against Table I (see
//! `CAL_*` constants below, and EXPERIMENTS.md E1 for measured vs paper).

use crate::accel::conv3d::ConvUnit;
use crate::accel::fusion::FusionPlan;
use crate::config::{AccelConfig, Layer, Network};
use crate::fpga::bram::bram18_for;
use crate::fpga::dsp::AdderTree;
use crate::util::json::Json;

/// Calibration of the LUT/FF model `cost = fixed + per_layer·L + per_lane·N`
/// (+ the adder-tree terms computed structurally). Two constraints pin it:
/// Table I (conv1_1+conv1_2+pool1 = 603 lanes → 245,138 LUT / 465,002 FF)
/// and feasibility of the paper's own 7-layer fused configuration on the
/// same board (2,331 lanes must stay under 433,200 LUT / 866,400 FF). The
/// split that satisfies both puts most of the cost in fixed infrastructure
/// (AXI/DDR interfacing, stream routing, control) — consistent with the
/// paper's Table I where LUT% ≫ DSP%.
const CAL_LUT_PER_LANE: usize = 42;
const CAL_LUT_PER_LAYER: usize = 6_000;
const CAL_LUT_FIXED: usize = 175_000;
const CAL_FF_PER_LANE: usize = 130;
const CAL_FF_PER_LAYER: usize = 4_000;
const CAL_FF_FIXED: usize = 340_000;
/// Control DSPs (address generators) — the +2 visible in Table I.
const CAL_DSP_OVERHEAD: usize = 2;

/// Resource usage of one configuration (a fused group or a whole plan).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Resources {
    pub dsp: usize,
    pub bram18: usize,
    pub lut: usize,
    pub ff: usize,
}

impl Resources {
    pub fn bram36(&self) -> usize {
        self.bram18.div_ceil(2)
    }

    pub fn add(&mut self, other: Resources) {
        self.dsp += other.dsp;
        self.bram18 += other.bram18;
        self.lut += other.lut;
        self.ff += other.ff;
    }

    pub fn max(&self, other: Resources) -> Resources {
        Resources {
            dsp: self.dsp.max(other.dsp),
            bram18: self.bram18.max(other.bram18),
            lut: self.lut.max(other.lut),
            ff: self.ff.max(other.ff),
        }
    }

    /// Component-wise saturating subtraction (used to split an envelope into
    /// shared-shell and incremental parts).
    pub fn saturating_sub(&self, other: Resources) -> Resources {
        Resources {
            dsp: self.dsp.saturating_sub(other.dsp),
            bram18: self.bram18.saturating_sub(other.bram18),
            lut: self.lut.saturating_sub(other.lut),
            ff: self.ff.saturating_sub(other.ff),
        }
    }

    /// Does this fit the platform budget?
    pub fn fits(&self, cfg: &AccelConfig) -> bool {
        let p = &cfg.platform;
        self.dsp <= p.dsp && self.bram36() <= p.bram36 && self.lut <= p.lut && self.ff <= p.ff
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("dsp", self.dsp)
            .set("bram18", self.bram18)
            .set("bram36", self.bram36())
            .set("lut", self.lut)
            .set("ff", self.ff)
    }
}

/// Utilization report against a platform (Table I format).
#[derive(Debug, Clone)]
pub struct Utilization {
    pub used: Resources,
    pub dsp_pct: f64,
    pub bram_pct: f64,
    pub lut_pct: f64,
    pub ff_pct: f64,
}

pub fn utilization(used: Resources, cfg: &AccelConfig) -> Utilization {
    let p = &cfg.platform;
    Utilization {
        used,
        dsp_pct: 100.0 * used.dsp as f64 / p.dsp as f64,
        bram_pct: 100.0 * used.bram36() as f64 / p.bram36 as f64,
        lut_pct: 100.0 * used.lut as f64 / p.lut as f64,
        ff_pct: 100.0 * used.ff as f64 / p.ff as f64,
    }
}

/// Resources of one layer instantiated inside a fused group.
pub fn layer_resources(cfg: &AccelConfig, net: &Network, li: usize) -> Resources {
    let in_sh = net.shape_before(li);
    let wb = cfg.platform.word_bytes * 8; // bits per channel value
    match &net.layers[li] {
        Layer::Conv {
            kernel,
            filters,
            ..
        } => {
            let unit = ConvUnit::for_layer(cfg, *kernel, in_sh.d, *filters);
            let lanes = unit.dsp_lanes();
            // Memories are organized at the datapath width d_par·32 bits:
            // iterative decomposition (§V) reads one depth-group slice per
            // cycle, so deeper-than-d_par layers store f_g words per pixel
            // (deeper, not wider — that is what keeps the 7-layer fusion
            // within the board's BRAM budget, as the paper's Table IV counts
            // imply).
            let word_bits = unit.d_par * wb;
            let line = kernel * bram18_for(in_sh.w * unit.d_groups, word_bits);
            // Filter banks: w·w BRAMs of k·f_g depth-group words each.
            let banks =
                kernel * kernel * bram18_for(*filters * unit.d_groups, word_bits);
            // Adder tree over the lanes + the serial-group accumulator.
            let tree = AdderTree::new(lanes.max(2), 18);
            Resources {
                dsp: lanes,
                bram18: line + banks,
                lut: tree.lut_cost(32) + lanes * CAL_LUT_PER_LANE + CAL_LUT_PER_LAYER,
                ff: tree.ff_cost(32) + lanes * CAL_FF_PER_LANE + CAL_FF_PER_LAYER,
            }
        }
        Layer::MaxPool { window, stride, .. } => {
            let d_par = cfg.depth_parallel(in_sh.d);
            let d_groups = cfg.depth_groups(in_sh.d);
            let word_bits = d_par * wb;
            let out_w = (in_sh.w - window) / stride + 1;
            Resources {
                dsp: 0,
                bram18: bram18_for(out_w * d_groups, word_bits),
                // comparators: one per channel lane
                lut: in_sh.d * 16 + CAL_LUT_PER_LAYER / 2,
                ff: in_sh.d * wb,
            }
        }
    }
}

/// The fixed per-board infrastructure folded into every [`group_resources`]
/// envelope: AXI/DDR interfacing, stream routing and control (the `CAL_*`
/// fixed terms plus the control DSPs). One board instantiates this shell
/// once, however many tenants it hosts — the multi-tenant placement planner
/// bills it per board and stacks each resident's *incremental* fabric
/// (`envelope − shell`) on top.
pub fn shell_resources() -> Resources {
    Resources {
        dsp: CAL_DSP_OVERHEAD,
        bram18: 0,
        lut: CAL_LUT_FIXED,
        ff: CAL_FF_FIXED,
    }
}

/// Resources of a fused group: all member layers instantiated concurrently.
pub fn group_resources(
    cfg: &AccelConfig,
    net: &Network,
    group: std::ops::Range<usize>,
) -> Resources {
    let mut total = Resources {
        dsp: CAL_DSP_OVERHEAD,
        lut: CAL_LUT_FIXED,
        ff: CAL_FF_FIXED,
        ..Resources::default()
    };
    for li in group {
        total.add(layer_resources(cfg, net, li));
    }
    total
}

/// Resources of a whole plan. Groups execute serially and the paper's §V
/// notes compute units are *reused* across groups, so the requirement is the
/// max over groups, not the sum (point A of Fig 7: "the computation unit of
/// single layer is reused for every layer").
pub fn plan_resources(cfg: &AccelConfig, net: &Network, plan: &FusionPlan) -> Resources {
    plan.groups()
        .into_iter()
        .map(|g| group_resources(cfg, net, g))
        .fold(Resources::default(), |acc, r| acc.max(r))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{vgg16_prefix, AccelConfig};

    fn cfg() -> AccelConfig {
        AccelConfig::paper_default()
    }

    #[test]
    fn table1_dsp_count_exact() {
        // Table I: first 2 conv + 1 pool of VGG-16 → 605 DSPs.
        // conv1_1: 9·3 = 27 lanes; conv1_2: 9·64 = 576 lanes; pool: 0; +2.
        let net = vgg16_prefix();
        let r = group_resources(&cfg(), &net, 0..3);
        assert_eq!(r.dsp, 605);
    }

    #[test]
    fn table1_bram_same_magnitude() {
        // Table I: 474 BRAMs (of 1470 BRAM36). Structural counting of line
        // buffers + filter banks + pool buffer must land in the same band.
        let net = vgg16_prefix();
        let r = group_resources(&cfg(), &net, 0..3);
        let b36 = r.bram36();
        assert!(
            (300..650).contains(&b36),
            "BRAM36 {b36} out of Table I band (paper: 474)"
        );
    }

    #[test]
    fn table1_lut_ff_same_magnitude() {
        // Table I: 245,138 LUTs / 465,002 FFs.
        let net = vgg16_prefix();
        let r = group_resources(&cfg(), &net, 0..3);
        assert!(
            (150_000..350_000).contains(&r.lut),
            "LUT {} vs paper 245k",
            r.lut
        );
        assert!(
            (350_000..600_000).contains(&r.ff),
            "FF {} vs paper 465k",
            r.ff
        );
    }

    #[test]
    fn utilization_under_budget() {
        let net = vgg16_prefix();
        let r = group_resources(&cfg(), &net, 0..3);
        let u = utilization(r, &cfg());
        assert!(r.fits(&cfg()));
        // Paper Table I: 16.8% DSP, 32.2% BRAM, 56.6% LUT, 53.7% FF.
        assert!((u.dsp_pct - 16.8).abs() < 0.1, "dsp {}%", u.dsp_pct);
        assert!(u.bram_pct < 100.0 && u.lut_pct < 100.0 && u.ff_pct < 100.0);
    }

    #[test]
    fn fig7_dsp_monotone_in_fusion() {
        // Fig 7: DSP utilization grows monotonically from no-fusion (A) to
        // full fusion (G) because fused layers are concurrently resident.
        let net = vgg16_prefix();
        let pts = crate::accel::fusion::fig7_points(&net);
        let mut last = 0usize;
        for (label, plan) in pts {
            let dsp = plan_resources(&cfg(), &net, &plan).dsp;
            assert!(dsp >= last, "point {label}: DSP {dsp} < previous {last}");
            last = dsp;
        }
    }

    #[test]
    fn unfused_uses_single_layer_peak() {
        let net = vgg16_prefix();
        let plan = FusionPlan::unfused(7);
        let per_layer_max = (0..7)
            .map(|i| group_resources(&cfg(), &net, i..i + 1).dsp)
            .max()
            .unwrap();
        assert_eq!(plan_resources(&cfg(), &net, &plan).dsp, per_layer_max);
    }

    #[test]
    fn full_fusion_fits_the_board() {
        // The paper ran the whole 7-layer prefix fused on the XC7V690T; the
        // structural count must respect that feasibility.
        let net = vgg16_prefix();
        let r = plan_resources(&cfg(), &net, &FusionPlan::fully_fused(7));
        assert!(
            r.fits(&cfg()),
            "full fusion must fit the XC7V690T: dsp {} bram36 {} lut {} ff {}",
            r.dsp,
            r.bram36(),
            r.lut,
            r.ff
        );
        // Table IV reports 2907 DSP / 2387 BRAM for this configuration —
        // same band as the structural count.
        assert!((1800..3400).contains(&r.dsp), "dsp {}", r.dsp);
        assert!((1400..3000).contains(&r.bram18), "bram18 {}", r.bram18);
    }

    #[test]
    fn pool_needs_no_dsp() {
        let net = vgg16_prefix();
        let r = layer_resources(&cfg(), &net, 2);
        assert_eq!(r.dsp, 0);
        assert!(r.bram18 > 0);
    }

    #[test]
    fn json_report() {
        let net = vgg16_prefix();
        let r = group_resources(&cfg(), &net, 0..3);
        let j = r.to_json();
        assert_eq!(j.get("dsp").as_usize(), Some(605));
        assert_eq!(j.get("bram36").as_usize(), Some(r.bram36()));
    }
}

pub mod energy;
