//! Energy model — the paper's motivation is energy-constrained mobile
//! robotics ("FPGAs have a much higher per-watt performance compared to
//! GPUs", §IV-C), but it never quantifies energy. This model does, using
//! standard per-event energy constants for 28 nm FPGAs (Horowitz ISSCC'14
//! class numbers), so the fusion trade-off can be read in millijoules:
//!
//! * a DSP 32-bit MAC:            ~20 pJ
//! * an on-chip BRAM access:      ~2.6 pJ per 32-bit word
//! * an off-chip DDR3 transfer: ~2600 pJ per 32-bit word (the 100–1000×
//!   gap between on-chip and off-chip is exactly why the paper's traffic
//!   reduction matters)
//! * static/clock-tree overhead:  ~0.8 W board baseline at 120 MHz

use crate::accel::engine::SimReport;
use crate::config::Network;

/// Per-event energy constants in picojoules.
#[derive(Debug, Clone, Copy)]
pub struct EnergyModel {
    pub pj_per_mac: f64,
    pub pj_per_bram_word: f64,
    pub pj_per_ddr_word: f64,
    /// Static + clock power in watts.
    pub static_watts: f64,
}

impl EnergyModel {
    /// 28 nm FPGA-class constants (see module docs).
    pub fn fpga_28nm() -> EnergyModel {
        EnergyModel {
            pj_per_mac: 20.0,
            pj_per_bram_word: 2.6,
            pj_per_ddr_word: 2600.0,
            static_watts: 0.8,
        }
    }

    /// CPU-class constants: a Xeon-class core spends ~1–2 nJ per effective
    /// MAC once fetch/decode/cache overheads are folded in.
    pub fn cpu_xeon() -> EnergyModel {
        EnergyModel {
            pj_per_mac: 1500.0,
            pj_per_bram_word: 10.0,  // L1/L2 word access
            pj_per_ddr_word: 5000.0, // DRAM + controller
            static_watts: 40.0,
        }
    }
}

/// Energy breakdown of one inference in millijoules.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyReport {
    pub compute_mj: f64,
    pub on_chip_mj: f64,
    pub off_chip_mj: f64,
    pub static_mj: f64,
}

impl EnergyReport {
    pub fn total_mj(&self) -> f64 {
        self.compute_mj + self.on_chip_mj + self.off_chip_mj + self.static_mj
    }

    /// Fraction of dynamic energy spent moving data off chip.
    pub fn off_chip_fraction(&self) -> f64 {
        let dynamic = self.compute_mj + self.on_chip_mj + self.off_chip_mj;
        if dynamic == 0.0 {
            0.0
        } else {
            self.off_chip_mj / dynamic
        }
    }
}

/// Energy of one simulated inference. On-chip word count is estimated as
/// 3 BRAM touches per MAC operand pair (window read, filter read, partial
/// write) — the streaming design's data reuse is already reflected in the
/// MAC count, so this is a stable structural estimate.
pub fn inference_energy(
    model: &EnergyModel,
    net: &Network,
    report: &SimReport,
    freq_mhz: f64,
) -> EnergyReport {
    let macs = net.total_macs() as f64;
    let compute_mj = macs * model.pj_per_mac * 1e-9;
    let on_chip_words = macs * 3.0;
    let on_chip_mj = on_chip_words * model.pj_per_bram_word * 1e-9;
    let ddr_words = (report.ddr_read_bytes + report.ddr_write_bytes) as f64 / 4.0;
    let off_chip_mj = ddr_words * model.pj_per_ddr_word * 1e-9;
    let seconds = report.total_cycles as f64 / (freq_mhz * 1e6);
    let static_mj = model.static_watts * seconds * 1e3;
    EnergyReport {
        compute_mj,
        on_chip_mj,
        off_chip_mj,
        static_mj,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{Engine, FusionPlan, Weights};
    use crate::config::{vgg16_prefix, AccelConfig};

    fn reports() -> (Network, SimReport, SimReport) {
        let cfg = AccelConfig::paper_default();
        let net = vgg16_prefix();
        let w = Weights::random(&net, 1);
        let e = Engine::new(cfg);
        let fused = e.simulate(&net, &w, &FusionPlan::fully_fused(7));
        let unfused = e.simulate(&net, &w, &FusionPlan::unfused(7));
        (net, fused, unfused)
    }

    #[test]
    fn fusion_saves_energy_via_traffic() {
        let (net, fused, unfused) = reports();
        let m = EnergyModel::fpga_28nm();
        let ef = inference_energy(&m, &net, &fused, 120.0);
        let eu = inference_energy(&m, &net, &unfused, 120.0);
        // Compute energy identical (same MACs); off-chip energy much lower.
        assert_eq!(ef.compute_mj, eu.compute_mj);
        assert!(
            eu.off_chip_mj > 10.0 * ef.off_chip_mj,
            "fused {} vs unfused {} mJ off-chip",
            ef.off_chip_mj,
            eu.off_chip_mj
        );
        assert!(ef.total_mj() < eu.total_mj());
    }

    #[test]
    fn fusion_collapses_off_chip_energy_share() {
        let (net, fused, unfused) = reports();
        let m = EnergyModel::fpga_28nm();
        let ef = inference_energy(&m, &net, &fused, 120.0);
        let eu = inference_energy(&m, &net, &unfused, 120.0);
        // The paper's §II argument quantified: unfused execution spends a
        // quarter of its dynamic energy on DDR; fusion collapses that share
        // by an order of magnitude.
        assert!(
            eu.off_chip_fraction() > 0.2,
            "unfused off-chip fraction {}",
            eu.off_chip_fraction()
        );
        assert!(
            eu.off_chip_fraction() > 8.0 * ef.off_chip_fraction(),
            "fused {} vs unfused {}",
            ef.off_chip_fraction(),
            eu.off_chip_fraction()
        );
    }

    #[test]
    fn magnitudes_sane() {
        // VGG prefix ≈ 5.5 GMACs → ~110 mJ compute at 20 pJ/MAC. Whole
        // inference should land in the 0.05–2 J band, not µJ, not kJ.
        let (net, fused, _) = reports();
        let m = EnergyModel::fpga_28nm();
        let e = inference_energy(&m, &net, &fused, 120.0);
        assert!(
            (50.0..2000.0).contains(&e.total_mj()),
            "total {} mJ",
            e.total_mj()
        );
    }

    #[test]
    fn cpu_class_burns_more() {
        let (net, fused, _) = reports();
        let fpga = inference_energy(&EnergyModel::fpga_28nm(), &net, &fused, 120.0);
        // CPU "runs" the same MACs with CPU-class constants over a 1 s
        // nominal runtime (conservative vs our measured multi-second runs).
        let m = EnergyModel::cpu_xeon();
        let macs = net.total_macs() as f64;
        let cpu_mj = macs * m.pj_per_mac * 1e-9 + m.static_watts * 1.0 * 1e3;
        assert!(
            cpu_mj > 10.0 * fpga.total_mj(),
            "cpu {} vs fpga {} mJ",
            cpu_mj,
            fpga.total_mj()
        );
    }
}
