//! DeCoILFNet reproduction library. See DESIGN.md for the system map.
pub mod accel;
pub mod baselines;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod fpga;
pub mod resources;
pub mod runtime;
pub mod tensor;
pub mod util;
pub mod verify;
