//! Offline stub of the `xla` crate (PJRT bindings).
//!
//! The real bindings need the XLA shared libraries, which the offline build
//! environment does not ship. This stub keeps `decoilfnet::runtime` (and the
//! server/verify paths above it) compiling: every entry point returns an
//! "unavailable" error at runtime. Tests that need PJRT already skip
//! themselves when `artifacts/manifest.json` is absent, so a stubbed runtime
//! never fails the suite. Dropping in the real crate requires no source
//! changes in the main package.

use std::fmt;

/// Stub error: always "PJRT unavailable".
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: XLA/PJRT bindings are unavailable in this offline build (stub `xla` crate)"
    )))
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: AsRef<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Element types a stub literal can extract.
pub trait ElementType {}
impl ElementType for f32 {}

/// Host literal (stub).
#[derive(Clone)]
pub struct Literal;

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]).reshape(&[2]).unwrap();
        assert!(lit.to_tuple1().is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
