//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! implements exactly the subset the repo uses: [`Error`] with a context
//! chain, the [`Context`] extension trait for `Result` and `Option`, the
//! [`anyhow!`]/[`bail!`] macros, and the [`Result`] alias. `{:#}` formatting
//! prints the full cause chain, like real anyhow.

use std::fmt;

/// An error with an optional chain of wrapped causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            msg: m.to_string(),
            source: None,
        }
    }

    fn wrap(self, ctx: impl fmt::Display) -> Error {
        Error {
            msg: ctx.to_string(),
            source: Some(Box::new(self)),
        }
    }

    fn write_chain(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            write!(f, ": {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole context chain, outermost first.
            self.write_chain(f)
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.write_chain(f)
    }
}

// Note: `Error` deliberately does not implement `std::error::Error`, so the
// blanket conversion below cannot conflict with the reflexive `From`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the std source chain into our own.
        let mut msgs = vec![e.to_string()];
        let mut cur: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = cur {
            msgs.push(s.to_string());
            cur = s.source();
        }
        let mut err = Error::msg(msgs.pop().unwrap());
        while let Some(m) = msgs.pop() {
            err = err.wrap(m);
        }
        err
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting its error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| e.into().wrap(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($t:tt)*) => {
        $crate::Error::msg(format!($($t)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn context_chain_formats() {
        let r: Result<()> = Err(io_err().into());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: missing");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("absent").unwrap_err();
        assert_eq!(format!("{e}"), "absent");
    }

    #[test]
    fn macros() {
        let e = anyhow!("x = {}", 3);
        assert_eq!(format!("{e}"), "x = 3");
        fn f() -> Result<()> {
            bail!("nope {}", 1)
        }
        assert_eq!(format!("{:#}", f().unwrap_err()), "nope 1");
    }
}
