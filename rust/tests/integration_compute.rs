//! Acceptance properties of the shared compute-kernel layer
//! (`accel::kernels`):
//!
//! * the im2col + blocked-MAC path is **bit-exact** against the naive
//!   per-pixel/per-channel oracle across randomized layer shapes — odd
//!   widths, padding < kernel, depths past the `max_depth_parallel` cap
//!   (serial depth-concat groups), with and without ReLU and threading;
//! * the engine's functional forward (now routed through the kernels)
//!   agrees with the independent f32 `cpu_ref` oracle to quantization
//!   tolerance on a whole network;
//! * all functional forwards in the repo (engine, Zhang'15 tiled baseline,
//!   fused-layer baseline) are one implementation: bit-equal outputs.

use decoilfnet::accel::kernels::{self, conv2d_fx, naive, KernelScratch};
use decoilfnet::accel::{Engine, Weights};
use decoilfnet::baselines::{cpu_ref, fused_layer, optimized};
use decoilfnet::config::{paper_test_example, tiny_vgg, AccelConfig, Layer, Network, VolShape};
use decoilfnet::tensor::NdTensor;
use decoilfnet::util::prng::Rng;
use decoilfnet::util::prop;

/// Randomized single-layer bit-exactness: kernel path vs naive oracle.
#[test]
fn kernel_path_bit_exact_vs_naive_across_shapes() {
    prop::check(
        "integration-kernel-vs-naive",
        prop::PropConfig {
            cases: 64,
            ..Default::default()
        },
        |r: &mut Rng| {
            // Odd widths and non-square extents on purpose; kernel extents
            // beyond the paper's 3×3 (1×1 degenerates the clip runs, 5×5
            // clips both borders at once); padding strictly below the
            // kernel; depths crossing tile and word boundaries.
            let kernel = [1usize, 3, 5][r.below(3) as usize];
            let pad = r.range_usize(0, kernel - 1);
            let h = (2 * r.range_usize(1, 8) + 1).max(kernel);
            let w = r.range_usize(3, 15).max(kernel);
            let d = r.range_usize(1, 12);
            let k = r.range_usize(1, 12);
            let threads = 1 + r.below(4) as usize;
            (h, w, d, k, kernel, pad, threads, r.chance(0.5), r.next_u64())
        },
        |&(h, w, d, k, kernel, pad, threads, relu, seed)| {
            let filt = NdTensor::random(&[k, kernel, kernel, d], seed ^ 1, -0.5, 0.5);
            let bias = NdTensor::random(&[k], seed ^ 2, -0.1, 0.1);
            let banks = decoilfnet::accel::depth_concat::FilterBanks::from_tensor(&filt, &bias);
            let input = NdTensor::random(&[h, w, d], seed ^ 3, -1.0, 1.0).to_fixed();
            let mut scratch = KernelScratch::new();
            let fast = conv2d_fx(&input, &banks, pad, relu, threads, &mut scratch);
            let slow = naive::conv2d_fx_naive(&input, &banks, pad, relu);
            if fast == slow {
                Ok(())
            } else {
                Err(format!(
                    "h={h} w={w} d={d} k={k} kernel={kernel} pad={pad} threads={threads}"
                ))
            }
        },
    );
}

/// Whole-network bit-exactness with serial depth-concat groups: a config
/// whose `max_depth_parallel` forces iterative decomposition must still be
/// value-identical (grouping only reorders hardware, never math).
#[test]
fn depth_concat_groups_never_change_values() {
    let net = Network {
        name: "deep-narrow".into(),
        input: VolShape::new(9, 9, 3),
        layers: vec![
            Layer::conv3x3("c1", 24),
            Layer::conv3x3("c2", 24),
            Layer::pool2x2("p"),
            Layer::conv3x3("c3", 40),
        ],
    };
    let w = Weights::random(&net, 5);
    let input = NdTensor::random(&net.input.as_slice(), 6, -1.0, 1.0);
    // Depth caps 1, 7 and 64 give 24, 4 and 1 serial groups respectively.
    let mut outs = Vec::new();
    for cap in [1usize, 7, 64] {
        let mut cfg = AccelConfig::paper_default();
        cfg.max_depth_parallel = cap;
        outs.push(Engine::new(cfg).forward_fx(&net, &w, &input));
    }
    assert_eq!(outs[0], outs[1]);
    assert_eq!(outs[1], outs[2]);
    // And the naive oracle agrees bit-for-bit.
    let oracle = naive::forward_network_fx_naive(&net, &w, &input.to_fixed());
    assert_eq!(outs[0], oracle);
}

/// The engine's kernel-routed forward vs the independent f32 CPU baseline:
/// quantization-tolerance agreement on a whole network (the f32 path is the
/// cross-implementation oracle; bitwise equality is impossible across
/// number formats).
#[test]
fn kernel_forward_tracks_cpu_ref_within_quantization() {
    let net = tiny_vgg();
    let seed = 23;
    let wf = cpu_ref::CpuWeights::random(&net, seed);
    let wx = Weights::random(&net, seed);
    let input = NdTensor::random(&net.input.as_slice(), 8, -1.0, 1.0);
    let cpu = cpu_ref::forward(&net, &wf, &input);
    let fx = Engine::new(AccelConfig::paper_default())
        .forward_fx(&net, &wx, &input)
        .to_f32();
    let diff = cpu.max_abs_diff(&fx);
    assert!(diff < 5e-3, "kernel path drifted from the f32 oracle: {diff}");
}

/// One compute implementation: engine, tiled Zhang'15 forward, and
/// fused-layer forward emit bit-identical tensors.
#[test]
fn all_functional_forwards_are_one_implementation() {
    let net = paper_test_example();
    let w = Weights::random(&net, 9);
    let input = NdTensor::random(&net.input.as_slice(), 10, -1.0, 1.0);
    let accel = AccelConfig::paper_default();
    let engine = Engine::new(accel.clone()).forward_fx(&net, &w, &input);
    let tiled = optimized::forward_fx(
        &optimized::OptimizedConfig::zhang2015(),
        &accel,
        &net,
        &w,
        &input.to_fixed(),
    );
    let fused = fused_layer::forward_fx(&net, &w, &input.to_fixed());
    assert_eq!(engine, tiled);
    assert_eq!(engine, fused);
}

/// Scratch reuse across a whole net equals per-layer fresh scratch, and the
/// thread count never leaks into values at network scale.
#[test]
fn network_forward_invariant_to_scratch_and_threads() {
    let net = tiny_vgg();
    let w = Weights::random(&net, 12);
    let input = NdTensor::random(&net.input.as_slice(), 13, -1.0, 1.0).to_fixed();
    let mut shared = KernelScratch::new();
    let base = kernels::forward_network_fx(&net, &w, &input, 1, &mut shared);
    for threads in [2usize, 5, 16] {
        let mut fresh = KernelScratch::new();
        let out = kernels::forward_network_fx(&net, &w, &input, threads, &mut fresh);
        assert_eq!(base, out, "threads={threads}");
    }
}
