//! Million-request fast-path smoke test (`#[ignore]`-gated — run with
//! `cargo test --release --test perf_smoke -- --ignored`).
//!
//! ROADMAP's "million-request scale" item targets whole-fleet traces of
//! 1e6+ requests in CI-budget wall time. This test pins the two structural
//! invariants the fast-path rewrite bought, independent of wall clock (the
//! machine-dependent half rides as `sim_events_per_sec` in
//! `BENCH_cluster.json`):
//!
//! * **Event budget** — the multi-tenant engine completes the trace in at
//!   most ~2 events per request (one arrival-cursor pop + one flush pop;
//!   batching only lowers it) plus a fixed controller/fault allowance.
//! * **O(boards) heap depth** — with same-instant flushes coalesced per
//!   event id, heap depth is bounded by the id universe (boards + tenant
//!   arrival cursors + a small margin), never by in-flight requests. A
//!   million queued requests may not grow the heap past ~10 entries.

use decoilfnet::accel::{FusionPlan, Weights};
use decoilfnet::cluster::{
    place_tenants, simulate_fleet_multi_tenant_traced, TenantWorkload, TraceSink,
};
use decoilfnet::config::{tiny_vgg, AccelConfig, ClusterConfig, ShardMode, SloPolicy, TenantSpec};

#[test]
#[ignore = "1e6-request perf smoke; minutes of wall time in debug builds"]
fn million_requests_stay_within_event_and_heap_budgets() {
    const TENANTS: usize = 4;
    const BOARDS: usize = 2; // one replicated pool of 2 boards per pair
    const REQUESTS_PER_TENANT: usize = 250_000;
    const TOTAL: usize = TENANTS * REQUESTS_PER_TENANT;

    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(); BOARDS];
    let specs: Vec<TenantSpec> = (0..TENANTS)
        .map(|t| TenantSpec {
            name: format!("tenant{t}"),
            network: tiny_vgg(),
            weights_seed: t as u64 + 1,
            // Two Poisson streams, two open-loop bursts: the bursts flood
            // their queues immediately, which is exactly the regime where
            // an uncoalesced heap would balloon with in-flight items.
            arrival_rps: if t % 2 == 0 { 50_000.0 } else { f64::INFINITY },
            requests: REQUESTS_PER_TENANT,
            load_steps: vec![],
            mode: ShardMode::Replicated,
            replicas: None,
            slo: SloPolicy {
                p99_ms: 5_000.0,
                priority: 1,
                weight: 1.0,
                overload: None,
            },
        })
        .collect();

    let weights: Vec<Weights> = specs
        .iter()
        .map(|s| Weights::random(&s.network, s.weights_seed))
        .collect();
    let fused = FusionPlan::fully_fused(7);
    let workloads: Vec<TenantWorkload> = specs
        .iter()
        .zip(&weights)
        .map(|(s, w)| TenantWorkload {
            name: &s.name,
            net: &s.network,
            weights: w,
            plan: &fused,
            mode: s.mode,
            priority: s.slo.priority,
            replicas: s.replicas,
        })
        .collect();
    let plans = place_tenants(&fleet, &workloads).expect("tenants place");

    let mut c = ClusterConfig::fleet_default();
    c.boards = BOARDS;
    c.mode = ShardMode::Replicated;
    c.board_specs = vec![];
    c.link_bytes_per_cycle = f64::INFINITY;
    c.link_latency_cycles = 0;
    c.aggregate_ddr_bytes_per_cycle = None;
    c.arrival_rps = f64::INFINITY;
    c.requests = 1;
    c.seed = 97;
    c.max_batch = 32;
    c.max_wait_us = 0.0;
    c.tenants = vec![];

    let mut sink = TraceSink::enabled();
    let r = simulate_fleet_multi_tenant_traced(&cfg, &fleet, &specs, &weights, &plans, &c, &mut sink);
    let tel = sink.summary().expect("armed sink yields a summary");

    assert_eq!(r.completed, TOTAL, "every request completes exactly once");

    // Event budget: ≤ 1 arrival pop + 1 flush pop per request, plus a fixed
    // allowance for batching bookkeeping. Violations mean the engine has
    // regressed into per-item event churn.
    let budget = 2 * TOTAL as u64 + 10_000;
    assert!(
        tel.sim_events <= budget,
        "event budget blown: {} sim events > {} for {} requests",
        tel.sim_events,
        budget,
        TOTAL,
    );

    // Coalesced heap depth: bounded by the id universe (boards + tenant
    // arrival cursors + margin), regardless of the million queued requests.
    let id_bound = (BOARDS + TENANTS + 2) as u64;
    assert!(
        tel.heap_depth_max <= id_bound,
        "heap depth must stay O(boards): max {} > {}",
        tel.heap_depth_max,
        id_bound,
    );
    assert!(
        tel.heap_depth_mean <= id_bound as f64,
        "mean heap depth must stay O(boards): {}",
        tel.heap_depth_mean,
    );

    eprintln!(
        "perf smoke: {} requests, {} sim events ({:.2}/request), heap depth max {} mean {:.2}",
        TOTAL,
        tel.sim_events,
        tel.sim_events as f64 / TOTAL as f64,
        tel.heap_depth_max,
        tel.heap_depth_mean,
    );
}
