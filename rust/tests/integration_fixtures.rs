//! Golden-fixture regression tests for the fleet simulators.
//!
//! The event-queue rewrites (PR 3) were proven byte-identical to the
//! pre-rewrite linear walks by differential tests against
//! `cluster/sim_legacy.rs`; with that equivalence confirmed over several CI
//! cycles the legacy module retired, and these committed `FleetReport`
//! snapshots under `tests/fixtures/` are the regression oracle now. Each
//! scenario pins one simulator behavior class:
//!
//! * `static_replicated_burst` — size-bound flushes plus the final
//!   deadline-flushed tail (100 requests over 4×8 batch slots);
//! * `static_replicated_poisson` — open-loop arrivals with time-based
//!   batch flushes draining through the `DeadlineQueue`;
//! * `static_pipelined_links` — stage chains over finite serializing
//!   `LinkChannel`s;
//! * `static_loadstep_contended` — a mid-run traffic step under shared-DDR
//!   contention;
//! * `dynamic_hetero_greedy` — the `BoardPool` greedy dispatcher on a
//!   two-generation fleet;
//! * `dynamic_loadstep_reshard` — the PR-2 fixture: naive pipelined cuts,
//!   traffic stepping past capacity, the re-shard controller migrating;
//! * `multi_tenant_spike` — two tenants under strict priorities with
//!   preemption (the PR-4 acceptance scenario; `PreemptMode::Restart`
//!   reproduces it unchanged);
//! * `mt_resume_spike` — the same inputs under work-preserving
//!   (`PreemptMode::Resume`) preemption;
//! * `mt_reshard_loadstep` — the unified control plane: a capped stream's
//!   load step blows its SLO, the tenant-aware controller uncaps it onto
//!   both boards and bills the migration (this PR's acceptance scenario).
//!
//! New scenarios self-seed: a missing fixture file is written on the first
//! run and reported, so it can be committed (the bench-baseline arming
//! pattern); every later run compares against the committed bytes. On CI
//! (`GITHUB_ACTIONS` set) self-seeding is disabled and a missing fixture
//! fails with commit instructions — a seedable scenario can never stay
//! green on main without its committed oracle.
//!
//! Comparison is structural: integers and strings must match exactly;
//! floats within 1e-9 relative (the committed values were produced by an
//! exact model mirror — the slack only forgives last-ulp noise, never a
//! behavioral change). Arrival sampling goes through the portable
//! `util::math::ln_det`, so the fixtures are platform-independent.
//!
//! To regenerate after an *intentional* model change:
//! `DECOILFNET_UPDATE_FIXTURES=1 cargo test --test integration_fixtures`
//! then commit the diff (and review it like any other behavioral diff).

use std::path::PathBuf;

use decoilfnet::accel::latency::group_cost_estimate;
use decoilfnet::accel::{FusionPlan, Weights};
use decoilfnet::cluster::{
    balance_min_max, place_tenants, simulate_fleet, simulate_fleet_dynamic,
    simulate_fleet_multi_tenant, InterBoardLink, ShardPlan, TenantWorkload,
};
use decoilfnet::config::{
    tiny_vgg, vgg16_prefix, AccelConfig, ClusterConfig, LoadStep, Network, Platform,
    PreemptMode, ReshardPolicy, ShardMode, SloPolicy, TenantSpec,
};
use decoilfnet::util::json::{parse, Json};

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name)
}

/// Fixtures authored in a toolchain-less environment that may self-seed on
/// their first run (the bench-baseline arming pattern): written, reported,
/// and expected to be committed from that run's artifact. Only names on
/// this allowlist may seed — a missing *committed* fixture stays a hard
/// failure, never a silent regenerate-and-pass.
const SEEDABLE_FIXTURES: &[&str] = &["mt_resume_spike.json", "mt_reshard_loadstep.json"];

/// Compare a report against its committed fixture, or regenerate it when
/// `DECOILFNET_UPDATE_FIXTURES=1`. A [`SEEDABLE_FIXTURES`] file that does
/// not exist yet is *seeded*: written and reported, so the brand-new
/// scenario passes its first run and the generated file can be committed —
/// every later run compares.
///
/// Seeding is a local-authoring affordance only: on CI (`GITHUB_ACTIONS`
/// set) an allowlisted-but-uncommitted fixture is a hard failure, so a
/// seedable scenario can never ride green on main without its oracle.
fn assert_matches_fixture(name: &str, actual: &Json) {
    let path = fixture_path(name);
    let update = std::env::var("DECOILFNET_UPDATE_FIXTURES").map(|v| v == "1") == Ok(true);
    if !update && !path.exists() && std::env::var_os("GITHUB_ACTIONS").is_some() {
        panic!(
            "fixture {name} is not committed (self-seeding is disabled on CI): \
             run `cargo test --test integration_fixtures` locally and commit \
             rust/tests/fixtures/{name}"
        );
    }
    if update || (!path.exists() && SEEDABLE_FIXTURES.contains(&name)) {
        std::fs::write(&path, actual.to_string_pretty() + "\n")
            .unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
        eprintln!(
            "{} fixture {name} — commit the generated file",
            if update { "regenerated" } else { "seeded" }
        );
        return;
    }
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading fixture {}: {e}", path.display()));
    let expected = parse(&text).unwrap_or_else(|e| panic!("parsing {}: {e}", path.display()));
    let mut diffs = Vec::new();
    diff_json("$", &expected, actual, &mut diffs);
    assert!(
        diffs.is_empty(),
        "report diverged from fixture {name} at:\n  {}\n\
         (intentional model change? regenerate with \
         DECOILFNET_UPDATE_FIXTURES=1 and commit the diff)\nactual:\n{}",
        diffs.join("\n  "),
        actual.to_string_pretty()
    );
}

/// Structural comparison: exact except floats at 1e-9 relative tolerance.
fn diff_json(path: &str, want: &Json, got: &Json, out: &mut Vec<String>) {
    match (want, got) {
        (Json::Num(a), Json::Num(b)) => {
            let tol = 1e-9 * a.abs().max(b.abs()).max(1.0);
            if (a - b).abs() > tol {
                out.push(format!("{path}: {a} vs {b}"));
            }
        }
        (Json::Obj(a), Json::Obj(b)) => {
            for k in a.keys().chain(b.keys().filter(|k| !a.contains_key(*k))) {
                match (a.get(k), b.get(k)) {
                    (Some(x), Some(y)) => diff_json(&format!("{path}.{k}"), x, y, out),
                    (Some(_), None) => out.push(format!("{path}.{k}: missing from report")),
                    (None, Some(_)) => out.push(format!("{path}.{k}: not in fixture")),
                    (None, None) => unreachable!(),
                }
            }
        }
        (Json::Arr(a), Json::Arr(b)) => {
            if a.len() != b.len() {
                out.push(format!("{path}: array len {} vs {}", a.len(), b.len()));
            } else {
                for (i, (x, y)) in a.iter().zip(b).enumerate() {
                    diff_json(&format!("{path}[{i}]"), x, y, out);
                }
            }
        }
        (a, b) => {
            if a != b {
                out.push(format!("{path}: {a:?} vs {b:?}"));
            }
        }
    }
}

fn setup() -> (AccelConfig, Network, Weights) {
    let net = vgg16_prefix();
    let w = Weights::random(&net, 1);
    (AccelConfig::paper_default(), net, w)
}

fn slow_gen(base: &AccelConfig) -> AccelConfig {
    AccelConfig {
        platform: Platform::virtex7_older_gen(),
        ..base.clone()
    }
}

/// Base config with every workload knob set explicitly, so fixture inputs
/// never drift with `fleet_default()`.
fn fx_cfg(boards: usize, mode: ShardMode, requests: usize) -> ClusterConfig {
    let mut c = ClusterConfig::fleet_default();
    c.boards = boards;
    c.mode = mode;
    c.board_specs = vec![];
    c.link_bytes_per_cycle = f64::INFINITY;
    c.link_latency_cycles = 0;
    c.aggregate_ddr_bytes_per_cycle = None;
    c.arrival_rps = f64::INFINITY;
    c.load_steps = vec![];
    c.requests = requests;
    c.seed = 7;
    c.max_batch = 8;
    c.max_wait_us = 0.0;
    c.reshard = None;
    c.tenants = vec![];
    c.preempt_restart_cycles = 500;
    c
}

#[test]
fn fixture_static_replicated_burst() {
    let (cfg, net, w) = setup();
    let shard = ShardPlan::replicated(&cfg, &net, &w, &FusionPlan::fully_fused(7), 4);
    let mut ccfg = fx_cfg(4, ShardMode::Replicated, 100);
    ccfg.max_wait_us = 200.0;
    let r = simulate_fleet(&cfg, &shard, &ccfg);
    assert_matches_fixture("static_replicated_burst.json", &r.to_json());
}

#[test]
fn fixture_static_replicated_poisson() {
    let (cfg, net, w) = setup();
    let shard = ShardPlan::replicated(&cfg, &net, &w, &FusionPlan::fully_fused(7), 3);
    let mut ccfg = fx_cfg(3, ShardMode::Replicated, 200);
    ccfg.arrival_rps = 2000.0;
    ccfg.max_wait_us = 150.0;
    let r = simulate_fleet(&cfg, &shard, &ccfg);
    assert_matches_fixture("static_replicated_poisson.json", &r.to_json());
}

#[test]
fn fixture_static_pipelined_links() {
    let (cfg, net, w) = setup();
    let shard = ShardPlan::pipelined(&cfg, &net, &w, &FusionPlan::unfused(7), 3);
    let mut ccfg = fx_cfg(3, ShardMode::Pipelined, 96);
    ccfg.link_bytes_per_cycle = 8.0;
    ccfg.link_latency_cycles = 200;
    ccfg.max_batch = 4;
    let r = simulate_fleet(&cfg, &shard, &ccfg);
    assert_matches_fixture("static_pipelined_links.json", &r.to_json());
}

#[test]
fn fixture_static_loadstep_contended() {
    let (cfg, net, w) = setup();
    let shard = ShardPlan::replicated(&cfg, &net, &w, &FusionPlan::fully_fused(7), 2);
    let mut ccfg = fx_cfg(2, ShardMode::Replicated, 128);
    ccfg.arrival_rps = 500.0;
    ccfg.load_steps = vec![LoadStep {
        at_request: 48,
        rps: 4000.0,
    }];
    ccfg.max_wait_us = 200.0;
    ccfg.aggregate_ddr_bytes_per_cycle = Some(96.0);
    let r = simulate_fleet(&cfg, &shard, &ccfg);
    assert_matches_fixture("static_loadstep_contended.json", &r.to_json());
}

#[test]
fn fixture_dynamic_hetero_greedy() {
    let (cfg, net, w) = setup();
    let fleet = vec![cfg.clone(), cfg.clone(), slow_gen(&cfg), slow_gen(&cfg)];
    let shard = ShardPlan::replicated_fleet(&fleet, &net, &w, &FusionPlan::fully_fused(7));
    let mut ccfg = fx_cfg(4, ShardMode::Replicated, 160);
    ccfg.max_batch = 4;
    let r = simulate_fleet_dynamic(&cfg, &fleet, &net, &w, shard, &ccfg);
    assert_matches_fixture("dynamic_hetero_greedy.json", &r.to_json());
}

#[test]
fn fixture_dynamic_loadstep_reshard() {
    // The PR-2 load-step scenario: naive homogeneous cuts on a 2-fast +
    // 2-slow fleet, traffic stepping past capacity, controller armed.
    let (cfg, net, w) = setup();
    let fleet = vec![cfg.clone(), cfg.clone(), slow_gen(&cfg), slow_gen(&cfg)];
    let plan = FusionPlan::unfused(7);
    let totals: Vec<u64> = plan
        .groups()
        .iter()
        .map(|g| group_cost_estimate(&cfg, &net, g.clone()).total())
        .collect();
    let cuts = balance_min_max(&totals, fleet.len().min(totals.len()));
    let naive = ShardPlan::pipelined_fleet_with_cuts(&fleet, &net, &w, &plan, &cuts);

    let link = InterBoardLink::new(16.0, 64);
    let naive_cap = naive.capacity_rps(8, &link, cfg.platform.freq_mhz);
    let naive_item_ms: f64 = naive.shards.iter().map(|s| s.item_us()).sum::<f64>() / 1e3;

    let mut ccfg = fx_cfg(4, ShardMode::Pipelined, 256);
    ccfg.link_bytes_per_cycle = 16.0;
    ccfg.link_latency_cycles = 64;
    ccfg.arrival_rps = 0.4 * naive_cap;
    ccfg.load_steps = vec![LoadStep {
        at_request: 64,
        rps: 1.25 * naive_cap,
    }];
    ccfg.seed = 3;
    ccfg.max_wait_us = 200.0;
    ccfg.reshard = Some(ReshardPolicy {
        window: 24,
        util_skew: 0.25,
        p99_ms: 2.5 * naive_item_ms,
        cooldown_windows: 1,
        migration_factor: 1.0,
    });
    let r = simulate_fleet_dynamic(&cfg, &fleet, &net, &w, naive, &ccfg);
    assert!(
        !r.reshard_events.is_empty(),
        "the fixture scenario must exercise a re-shard"
    );
    assert_matches_fixture("dynamic_loadstep_reshard.json", &r.to_json());
}

#[test]
fn fixture_multi_tenant_spike() {
    // This PR's acceptance scenario: interactive tenant with a 1 ms SLO vs
    // a bulk tenant spiking to a burst at request 16.
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone()];
    let specs = spike_specs_for_fixture();
    let (weights, plans) = place_mt(&fleet, &specs);
    // Fleet-level `requests` is ignored on the multi-tenant path (each
    // tenant drives its own stream), but must still validate.
    let ccfg = fx_cfg(2, ShardMode::Replicated, 1);
    let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &weights, &plans, &ccfg);
    assert_matches_fixture("multi_tenant_spike.json", &r.to_json());
}

/// Fully-fused placement of replicated tiny tenants, shared by the
/// multi-tenant fixture scenarios.
fn place_mt(
    fleet: &[AccelConfig],
    specs: &[TenantSpec],
) -> (Vec<Weights>, Vec<ShardPlan>) {
    let weights: Vec<Weights> = specs
        .iter()
        .map(|s| Weights::random(&s.network, s.weights_seed))
        .collect();
    let fused = FusionPlan::fully_fused(7);
    let workloads: Vec<TenantWorkload> = specs
        .iter()
        .zip(&weights)
        .map(|(s, w)| TenantWorkload {
            name: &s.name,
            net: &s.network,
            weights: w,
            plan: &fused,
            mode: s.mode,
            priority: s.slo.priority,
            replicas: s.replicas,
        })
        .collect();
    let plans = place_tenants(fleet, &workloads).unwrap();
    (weights, plans)
}

/// The resume-mode spike: the `multi_tenant_spike` inputs bit-for-bit, but
/// preempted batches keep their finished prefixes and pay only the refill.
#[test]
fn fixture_mt_resume_spike() {
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone()];
    let specs = spike_specs_for_fixture();
    let (weights, plans) = place_mt(&fleet, &specs);
    let mut ccfg = fx_cfg(2, ShardMode::Replicated, 1);
    ccfg.preempt_mode = PreemptMode::Resume;
    ccfg.preempt_refill_cycles = 100;
    let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &weights, &plans, &ccfg);
    assert!(
        r.tenants[1].preemptions > 0,
        "the fixture scenario must exercise work-preserving preemption"
    );
    assert_matches_fixture("mt_resume_spike.json", &r.to_json());
}

/// The unified control plane under a load step: a capped stream blows its
/// SLO after its rate doubles, the controller uncaps it onto both boards
/// (one per-tenant `ReshardEvent`), and the tail settles again.
#[test]
fn fixture_mt_reshard_loadstep() {
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone()];
    let specs = vec![
        TenantSpec {
            name: "stream".to_string(),
            network: tiny_vgg(),
            weights_seed: 1,
            arrival_rps: 7500.0,
            requests: 320,
            load_steps: vec![LoadStep {
                at_request: 96,
                rps: 15000.0,
            }],
            mode: ShardMode::Replicated,
            replicas: Some(1),
            slo: SloPolicy {
                p99_ms: 0.5,
                priority: 2,
                weight: 1.0,
                overload: None,
            },
        },
        TenantSpec {
            name: "bulk".to_string(),
            network: tiny_vgg(),
            weights_seed: 2,
            arrival_rps: f64::INFINITY,
            requests: 64,
            load_steps: vec![],
            mode: ShardMode::Replicated,
            replicas: None,
            slo: SloPolicy {
                p99_ms: 5000.0,
                priority: 0,
                weight: 1.0,
                overload: None,
            },
        },
    ];
    let (weights, plans) = place_mt(&fleet, &specs);
    let mut ccfg = fx_cfg(2, ShardMode::Replicated, 1);
    ccfg.seed = 11;
    ccfg.link_bytes_per_cycle = 16.0;
    ccfg.link_latency_cycles = 64;
    ccfg.reshard = Some(ReshardPolicy {
        window: 48,
        util_skew: 0.9,
        p99_ms: 50.0,
        cooldown_windows: 1,
        migration_factor: 1.0,
    });
    let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &weights, &plans, &ccfg);
    assert!(
        !r.reshard_events.is_empty(),
        "the fixture scenario must exercise a tenant-aware re-shard"
    );
    assert!(r.reshard_events.iter().all(|e| e.tenant.is_some()));
    assert_matches_fixture("mt_reshard_loadstep.json", &r.to_json());
}

/// Spike tenant specs shared by the restart- and resume-mode fixtures
/// (identical inputs — only `preempt_mode` differs between the scenarios).
fn spike_specs_for_fixture() -> Vec<TenantSpec> {
    vec![
        TenantSpec {
            name: "interactive".to_string(),
            network: tiny_vgg(),
            weights_seed: 1,
            arrival_rps: 1500.0,
            requests: 48,
            load_steps: vec![],
            mode: ShardMode::Replicated,
            replicas: None,
            slo: SloPolicy {
                p99_ms: 1.0,
                priority: 2,
                weight: 1.0,
                overload: None,
            },
        },
        TenantSpec {
            name: "bulk".to_string(),
            network: tiny_vgg(),
            weights_seed: 2,
            arrival_rps: 800.0,
            requests: 96,
            load_steps: vec![LoadStep {
                at_request: 16,
                rps: f64::INFINITY,
            }],
            mode: ShardMode::Replicated,
            replicas: None,
            slo: SloPolicy {
                p99_ms: 2.0,
                priority: 0,
                weight: 1.0,
                overload: None,
            },
        },
    ]
}
