//! Graceful-degradation battery: overload shedding with client
//! retry/backoff, partial-capacity (brownout) faults, and their
//! composition — seeded, deterministic, and replayable per case.
//!
//! Properties held across ≥64 randomized scenarios:
//!
//! * **Shed conservation** — with an `OverloadPolicy` armed, every offered
//!   request either completes or is abandoned, exactly once:
//!   `offered == completed + abandoned` per tenant, and the fleet rollups
//!   equal the per-tenant sums. The shed/retry/abandon trace events agree
//!   with the report's counters one for one.
//! * **Co-tenant protection** — a best-effort tenant flooding its own
//!   admission queue never touches the policy-less interactive tenant: it
//!   is never shed, never abandons, completes in full, and holds its p99
//!   SLO through the flood (priority preemption plus shedding keep the
//!   queues it shares shallow).
//! * **Degrade-then-recover accounting** — a `ComputeDegrade` brownout
//!   scales service through the cost model while it holds and counts in
//!   the `FaultSummary`; at the battery's low load the post-recovery p99
//!   returns to within 1.25× of the pre-fault baseline, and the armed
//!   controller stamps a `recovery_time_ms` once its window p99 falls back
//!   inside that band.
//! * **No-policy byte-identity** — with no overload policy and no
//!   `ComputeDegrade`, the report JSON must not grow a single new key:
//!   the invariant that keeps every previously committed golden fixture
//!   byte-identical.
//!
//! The golden fixture (`overload_shed_brownout.json`) pins the full
//! `decoilfnet-fleet-trace/v1` document for a fixed flood-plus-brownout
//! scene, with the same self-seeding allowlist discipline as the other
//! fixture suites (never on CI).

use std::path::PathBuf;

use decoilfnet::accel::{FusionPlan, Weights};
use decoilfnet::cluster::{
    place_tenants, simulate_fleet_multi_tenant, simulate_fleet_multi_tenant_traced, ShardPlan,
    TenantWorkload, TraceSink,
};
use decoilfnet::config::{
    tiny_vgg, AccelConfig, ClusterConfig, FaultEvent, FaultScript, OverloadPolicy, PreemptMode,
    ReshardPolicy, RetryPolicy, ShardMode, SloPolicy, TenantSpec,
};
use decoilfnet::util::json::{parse, Json};
use decoilfnet::util::prop::{check, PropConfig};

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name)
}

/// Fixtures authored in a toolchain-less environment that may self-seed on
/// their first run — same allowlist discipline as `integration_fixtures.rs`:
/// only named files may seed, and never on CI.
const SEEDABLE_FIXTURES: &[&str] = &["overload_shed_brownout.json"];

/// Structural fixture comparison (exact except floats at 1e-9 relative),
/// with the same seed/update/CI semantics as `integration_fixtures.rs`.
fn assert_matches_fixture(name: &str, actual: &Json) {
    let path = fixture_path(name);
    let update = std::env::var("DECOILFNET_UPDATE_FIXTURES").map(|v| v == "1") == Ok(true);
    if !update && !path.exists() && std::env::var_os("GITHUB_ACTIONS").is_some() {
        panic!(
            "fixture {name} is not committed (self-seeding is disabled on CI): \
             run `cargo test --test integration_overload` locally and commit \
             rust/tests/fixtures/{name}"
        );
    }
    if update || (!path.exists() && SEEDABLE_FIXTURES.contains(&name)) {
        std::fs::write(&path, actual.to_string_pretty() + "\n")
            .unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
        eprintln!(
            "{} fixture {name} — commit the generated file",
            if update { "regenerated" } else { "seeded" }
        );
        return;
    }
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading fixture {}: {e}", path.display()));
    let expected = parse(&text).unwrap_or_else(|e| panic!("parsing {}: {e}", path.display()));
    let mut diffs = Vec::new();
    diff_json("$", &expected, actual, &mut diffs);
    assert!(
        diffs.is_empty(),
        "overload run diverged from fixture {name} at:\n  {}\n\
         (intentional model change? regenerate with \
         DECOILFNET_UPDATE_FIXTURES=1 and commit the diff)",
        diffs.join("\n  ")
    );
}

/// Structural comparison: exact except floats at 1e-9 relative tolerance.
fn diff_json(path: &str, want: &Json, got: &Json, out: &mut Vec<String>) {
    match (want, got) {
        (Json::Num(a), Json::Num(b)) => {
            let tol = 1e-9 * a.abs().max(b.abs()).max(1.0);
            if (a - b).abs() > tol {
                out.push(format!("{path}: {a} vs {b}"));
            }
        }
        (Json::Obj(a), Json::Obj(b)) => {
            for k in a.keys().chain(b.keys().filter(|k| !a.contains_key(*k))) {
                match (a.get(k), b.get(k)) {
                    (Some(x), Some(y)) => diff_json(&format!("{path}.{k}"), x, y, out),
                    (Some(_), None) => out.push(format!("{path}.{k}: missing from report")),
                    (None, Some(_)) => out.push(format!("{path}.{k}: not in fixture")),
                    (None, None) => unreachable!(),
                }
            }
        }
        (Json::Arr(a), Json::Arr(b)) => {
            if a.len() != b.len() {
                out.push(format!("{path}: array len {} vs {}", a.len(), b.len()));
            } else {
                for (i, (x, y)) in a.iter().zip(b).enumerate() {
                    diff_json(&format!("{path}[{i}]"), x, y, out);
                }
            }
        }
        (a, b) => {
            if a != b {
                out.push(format!("{path}: {a:?} vs {b:?}"));
            }
        }
    }
}

/// The protected tenant: a Poisson interactive stream, high priority, a
/// real p99 SLO, and — crucially — no overload policy: the shedding
/// machinery must never touch it.
fn interactive(requests: usize, rps: f64) -> TenantSpec {
    TenantSpec {
        name: "interactive".to_string(),
        network: tiny_vgg(),
        weights_seed: 1,
        arrival_rps: rps,
        requests,
        load_steps: vec![],
        mode: ShardMode::Replicated,
        replicas: None,
        slo: SloPolicy {
            p99_ms: 1.0,
            priority: 2,
            weight: 1.0,
            overload: None,
        },
    }
}

/// The flooding tenant: a saturating best-effort burst carrying the
/// overload policy under test.
fn flooder(requests: usize, policy: OverloadPolicy) -> TenantSpec {
    TenantSpec {
        name: "best-effort".to_string(),
        network: tiny_vgg(),
        weights_seed: 2,
        arrival_rps: f64::INFINITY,
        requests,
        load_steps: vec![],
        mode: ShardMode::Replicated,
        replicas: None,
        slo: SloPolicy {
            p99_ms: 5000.0,
            priority: 0,
            weight: 1.0,
            overload: Some(policy),
        },
    }
}

fn place(fleet: &[AccelConfig], specs: &[TenantSpec]) -> (Vec<Weights>, Vec<ShardPlan>) {
    let weights: Vec<Weights> = specs
        .iter()
        .map(|s| Weights::random(&s.network, s.weights_seed))
        .collect();
    let fused = FusionPlan::fully_fused(7);
    let workloads: Vec<TenantWorkload> = specs
        .iter()
        .zip(&weights)
        .map(|(s, w)| TenantWorkload {
            name: &s.name,
            net: &s.network,
            weights: w,
            plan: &fused,
            mode: s.mode,
            priority: s.slo.priority,
            replicas: s.replicas,
        })
        .collect();
    let plans = place_tenants(fleet, &workloads).unwrap();
    (weights, plans)
}

/// The battery's fleet config, shaped like the deterministic preemption
/// tests that pin the hi-priority protection bound: restart-mode
/// preemption, infinite wire, a single shared batch cap.
fn base_cfg(boards: usize, max_batch: usize, seed: u64) -> ClusterConfig {
    let mut c = ClusterConfig::fleet_default();
    c.boards = boards;
    c.mode = ShardMode::Replicated;
    c.board_specs = vec![];
    c.link_bytes_per_cycle = f64::INFINITY;
    c.link_latency_cycles = 0;
    c.aggregate_ddr_bytes_per_cycle = None;
    c.arrival_rps = f64::INFINITY;
    c.load_steps = vec![];
    c.requests = 1;
    c.max_batch = max_batch;
    c.max_wait_us = 0.0;
    c.seed = seed;
    c.reshard = None;
    c.tenants = vec![];
    c.preempt_mode = PreemptMode::Restart;
    c.preempt_restart_cycles = 500;
    c.preempt_refill_cycles = 100;
    c.faults = None;
    c
}

#[derive(Debug)]
struct ShedCase {
    boards: usize,
    max_batch: usize,
    flood: usize,
    max_queue: usize,
    max_attempts: u32,
    backoff_base_ms: f64,
    jitter: f64,
    seed: u64,
}

/// ≥64 seeded flood scenarios: shed conservation, rollup/trace agreement,
/// and co-tenant p99 protection.
#[test]
fn prop_shedding_conserves_offered_work_and_protects_the_co_tenant() {
    let cfg = AccelConfig::paper_default();
    check(
        "overload-shed-battery",
        PropConfig { cases: 64, seed: 0x5EDCA5E },
        |r| ShedCase {
            boards: r.range_usize(2, 3),
            max_batch: r.range_usize(2, 8),
            flood: [96, 160, 256][r.below(3) as usize],
            max_queue: r.range_usize(2, 8),
            max_attempts: r.range_u64(0, 3) as u32,
            backoff_base_ms: 0.05 + 0.05 * r.range_usize(0, 3) as f64,
            jitter: 0.25 * r.range_usize(0, 2) as f64,
            seed: r.range_u64(1, 1u64 << 40),
        },
        |case| {
            let fleet = vec![cfg.clone(); case.boards];
            let specs = vec![
                interactive(24, 2000.0),
                flooder(
                    case.flood,
                    OverloadPolicy {
                        // Generous deadline: queue depth is the shedding
                        // driver, so the case split (retry vs abandon) is
                        // controlled by max_attempts alone.
                        deadline_ms: 50.0,
                        max_queue: case.max_queue,
                        retry: RetryPolicy {
                            max_attempts: case.max_attempts,
                            backoff_base_ms: case.backoff_base_ms,
                            jitter: case.jitter,
                        },
                    },
                ),
            ];
            let (weights, plans) = place(&fleet, &specs);
            let mut ccfg = base_cfg(case.boards, case.max_batch, case.seed);
            ccfg.tenants = specs.clone();
            let mut sink = TraceSink::enabled();
            let r = simulate_fleet_multi_tenant_traced(
                &cfg, &fleet, &specs, &weights, &plans, &ccfg, &mut sink,
            );
            let (hi, lo) = (&r.tenants[0], &r.tenants[1]);

            // Co-tenant protection: the policy-less tenant is untouched.
            if hi.completed != 24 {
                return Err(format!("interactive lost work: {}/24", hi.completed));
            }
            if hi.shed != Some(0) || hi.retried != Some(0) || hi.abandoned != Some(0) {
                return Err(format!(
                    "policy-less tenant touched by shedding: {:?}/{:?}/{:?}",
                    hi.shed, hi.retried, hi.abandoned
                ));
            }
            if !hi.slo_met {
                return Err(format!(
                    "flood broke the protected p99: {} > slo {}",
                    hi.p99_ms, hi.slo_p99_ms
                ));
            }

            // Shed conservation on the flooder.
            let (shed, retried, abandoned) = (
                lo.shed.ok_or("shed missing")?,
                lo.retried.ok_or("retried missing")?,
                lo.abandoned.ok_or("abandoned missing")?,
            );
            if lo.completed as u64 + abandoned != case.flood as u64 {
                return Err(format!(
                    "offered != completed + abandoned: {} + {abandoned} != {}",
                    lo.completed, case.flood
                ));
            }
            if shed == 0 {
                return Err(format!(
                    "a {}-burst into a {}-deep queue must shed",
                    case.flood, case.max_queue
                ));
            }
            if case.max_attempts == 0 {
                // No retry budget: every shed abandons on the spot.
                if retried != 0 || shed != abandoned {
                    return Err(format!(
                        "attempts=0 must abandon per shed: shed {shed} retried {retried} \
                         abandoned {abandoned}"
                    ));
                }
            } else if retried == 0 {
                return Err("shed requests with retry budget never came back".into());
            }
            let gp = lo.goodput_rps.ok_or("goodput missing")?;
            if gp > lo.throughput_rps + 1e-9 {
                return Err(format!(
                    "goodput {gp} exceeds offered-based throughput {}",
                    lo.throughput_rps
                ));
            }

            // Rollups and trace agree with the per-tenant counters.
            if r.shed_total != Some(shed)
                || r.retried_total != Some(retried)
                || r.abandoned_total != Some(abandoned)
            {
                return Err(format!(
                    "rollups diverge: {:?}/{:?}/{:?} vs {shed}/{retried}/{abandoned}",
                    r.shed_total, r.retried_total, r.abandoned_total
                ));
            }
            let count =
                |k: &str| sink.events.iter().filter(|e| e.kind() == k).count() as u64;
            for (label, want, got) in [
                ("shed", shed, count("shed")),
                ("retry", retried, count("retry")),
                ("abandon", abandoned, count("abandon")),
            ] {
                if want != got {
                    return Err(format!("{label}: counter {want} != trace {got}"));
                }
            }
            if r.completed != hi.completed + lo.completed {
                return Err(format!(
                    "fleet completed {} != tenant sum {}",
                    r.completed,
                    hi.completed + lo.completed
                ));
            }

            // Deterministic, jittered backoff and all: two plain runs agree
            // to the byte, and the armed sink never perturbed the outcome
            // (the `telemetry` key is the traced report's only delta).
            let r2 = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &weights, &plans, &ccfg);
            let r3 = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &weights, &plans, &ccfg);
            if r2.to_json().to_string_pretty() != r3.to_json().to_string_pretty() {
                return Err("shedding run is not byte-deterministic".into());
            }
            if r2.makespan_cycles != r.makespan_cycles
                || (r2.tenants[1].shed, r2.tenants[1].retried, r2.tenants[1].abandoned)
                    != (Some(shed), Some(retried), Some(abandoned))
            {
                return Err("armed trace sink perturbed the shed outcome".into());
            }
            Ok(())
        },
    );
}

#[derive(Debug)]
struct DegradeCase {
    boards: usize,
    degraded: usize,
    fraction: f64,
    fail_frac: f64,
    recover_frac: f64,
    max_batch: usize,
    seed: u64,
}

/// ≥32 seeded brownout scenarios at structural low load: capacity
/// accounting (`compute_degrades`), full conservation, bounded recovery of
/// the post-fault p99, and a stamped recovery time from the armed
/// controller.
#[test]
fn prop_degrade_then_recover_accounts_capacity() {
    let cfg = AccelConfig::paper_default();
    const REQUESTS: usize = 128;
    const RPS: f64 = 400.0;
    let span_ms = REQUESTS as f64 / RPS * 1e3;
    check(
        "overload-degrade-battery",
        PropConfig { cases: 32, seed: 0xB70_0D },
        |r| DegradeCase {
            boards: r.range_usize(2, 3),
            degraded: r.range_usize(0, 2),
            fraction: 0.2 + 0.1 * r.range_usize(0, 6) as f64,
            fail_frac: 0.30 + 0.01 * r.range_usize(0, 8) as f64,
            recover_frac: 0.52 + 0.01 * r.range_usize(0, 8) as f64,
            max_batch: r.range_usize(2, 8),
            seed: r.range_u64(1, 1u64 << 40),
        },
        |case| {
            let fleet = vec![cfg.clone(); case.boards];
            let degraded = case.degraded % case.boards;
            let specs = vec![interactive(REQUESTS, RPS), {
                let mut s = interactive(REQUESTS, RPS);
                s.name = "second".to_string();
                s.weights_seed = 2;
                s.slo.priority = 1;
                s
            }];
            let (weights, plans) = place(&fleet, &specs);
            let mut ccfg = base_cfg(case.boards, case.max_batch, case.seed);
            // Armed controller: brownouts trigger capacity-aware
            // re-placement and the recovery-time accounting.
            ccfg.reshard = Some(ReshardPolicy {
                window: 32,
                util_skew: 0.9,
                p99_ms: 50.0,
                cooldown_windows: 1,
                migration_factor: 0.0,
            });
            ccfg.tenants = specs.clone();
            ccfg.faults = Some(FaultScript {
                events: vec![FaultEvent::ComputeDegrade {
                    board: degraded,
                    capacity_fraction: case.fraction,
                    at_ms: span_ms * case.fail_frac,
                    recover_ms: Some(span_ms * case.recover_frac),
                }],
            });
            let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &weights, &plans, &ccfg);

            // A brownout sheds capacity, never requests.
            for t in &r.tenants {
                if t.completed != REQUESTS {
                    return Err(format!("{}: {}/{REQUESTS} completed", t.name, t.completed));
                }
            }
            let f = r.faults.as_ref().ok_or("faults summary missing")?;
            if f.compute_degrades != 1 {
                return Err(format!("compute_degrades {} != 1", f.compute_degrades));
            }
            if f.board_failures != 0 || f.items_requeued != 0 {
                return Err("a brownout is not an outage: nothing fails or requeues".into());
            }

            // Bounded recovery at structural low load, and the controller
            // stamps how long it took.
            let (pre, post) = match (f.pre_fault_p99_ms, f.recovery_p99_ms) {
                (Some(a), Some(b)) => (a, b),
                other => return Err(format!("pre/post p99 must both exist, got {other:?}")),
            };
            if post > 1.25 * pre {
                return Err(format!(
                    "recovery p99 {post:.4} ms > 1.25 × pre-fault p99 {pre:.4} ms"
                ));
            }
            let rto = f.recovery_time_ms.ok_or("recovery_time_ms missing")?;
            let makespan_ms =
                r.makespan_cycles as f64 / (cfg.platform.freq_mhz * 1e3);
            if !(rto > 0.0 && rto <= makespan_ms) {
                return Err(format!("RTO {rto} outside (0, {makespan_ms}]"));
            }

            // No overload policy in this scenario: the shed keys stay out
            // of the report even though a fault script is armed.
            let s = r.to_json().to_string_compact();
            for key in ["\"shed\"", "\"retried\"", "\"abandoned\"", "\"goodput_rps\""] {
                if s.contains(key) {
                    return Err(format!("degrade-only run grew {key}"));
                }
            }
            Ok(())
        },
    );
}

/// The fixed flood-plus-brownout scene behind the golden fixture: a
/// 256-request best-effort burst with retry/backoff, board 0 at 30%
/// capacity through the middle of the flood, controller armed.
fn shed_brownout_scene(
    fleet: &[AccelConfig],
) -> (Vec<TenantSpec>, Vec<Weights>, Vec<ShardPlan>, ClusterConfig) {
    let mut hi = interactive(64, 2000.0);
    hi.slo.p99_ms = 2.0; // brownout headroom: ~2 batch services at 30%
    let specs = vec![
        hi,
        flooder(
            256,
            OverloadPolicy {
                deadline_ms: 2.0,
                max_queue: 8,
                retry: RetryPolicy {
                    max_attempts: 3,
                    backoff_base_ms: 0.2,
                    jitter: 0.5,
                },
            },
        ),
    ];
    let (weights, plans) = place(fleet, &specs);
    let mut ccfg = base_cfg(2, 8, 7);
    ccfg.reshard = Some(ReshardPolicy {
        window: 16,
        util_skew: 0.9,
        p99_ms: 50.0,
        cooldown_windows: 1,
        migration_factor: 0.0,
    });
    ccfg.tenants = specs.clone();
    ccfg.faults = Some(FaultScript {
        events: vec![FaultEvent::ComputeDegrade {
            board: 0,
            capacity_fraction: 0.3,
            at_ms: 0.5,
            recover_ms: Some(3.0),
        }],
    });
    (specs, weights, plans, ccfg)
}

/// Overload shedding composes with a brownout: best-effort work sheds
/// first while the protected tenant completes in full with its SLO intact,
/// and the whole `decoilfnet-fleet-trace/v1` document is byte-stable and
/// pinned by the golden fixture.
#[test]
fn fixture_overload_shed_brownout() {
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone()];
    let (specs, weights, plans, ccfg) = shed_brownout_scene(&fleet);
    let mut sink = TraceSink::enabled();
    let r = simulate_fleet_multi_tenant_traced(
        &cfg, &fleet, &specs, &weights, &plans, &ccfg, &mut sink,
    );
    let (hi, lo) = (&r.tenants[0], &r.tenants[1]);
    assert_eq!(hi.completed, 64, "protected tenant completes in full");
    assert_eq!(hi.abandoned, Some(0));
    assert!(hi.slo_met, "hi p99 {} > slo {}", hi.p99_ms, hi.slo_p99_ms);
    assert!(lo.shed.unwrap() > 0, "the flood must shed");
    assert_eq!(
        lo.completed as u64 + lo.abandoned.unwrap(),
        256,
        "offered == completed + abandoned through the brownout"
    );
    let f = r.faults.as_ref().expect("script armed");
    assert_eq!(f.compute_degrades, 1);
    assert_eq!(f.board_failures, 0);

    let doc = Json::obj()
        .set("schema", "decoilfnet-fleet-trace/v1")
        .set("report", r.to_json())
        .set("trace", sink.to_json());
    // Byte-stability first: an identical in-process re-run must reproduce
    // the document exactly.
    let mut sink2 = TraceSink::enabled();
    let r2 = simulate_fleet_multi_tenant_traced(
        &cfg, &fleet, &specs, &weights, &plans, &ccfg, &mut sink2,
    );
    let doc2 = Json::obj()
        .set("schema", "decoilfnet-fleet-trace/v1")
        .set("report", r2.to_json())
        .set("trace", sink2.to_json());
    assert_eq!(
        doc.to_string_pretty(),
        doc2.to_string_pretty(),
        "flood + brownout runs must be byte-deterministic"
    );
    assert_matches_fixture("overload_shed_brownout.json", &doc);
}

/// Overload is strictly opt-in: the same scene with the policy stripped
/// and no fault script reports none of the new keys — the invariant that
/// keeps every previously committed golden fixture byte-identical.
#[test]
fn no_policy_means_no_shed_keys_anywhere() {
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone()];
    let (mut specs, weights, plans, mut ccfg) = shed_brownout_scene(&fleet);
    for s in &mut specs {
        s.slo.overload = None;
    }
    ccfg.tenants = specs.clone();
    ccfg.faults = None;
    let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &weights, &plans, &ccfg);
    assert!(r.faults.is_none());
    let s = r.to_json().to_string_compact();
    for key in [
        "\"faults\"",
        "slo_attainment_outage",
        "\"shed\"",
        "\"retried\"",
        "\"abandoned\"",
        "\"goodput_rps\"",
        "\"compute_degrades\"",
        "\"recovery_time_ms\"",
        "\"shed_total\"",
        "\"retried_total\"",
        "\"abandoned_total\"",
    ] {
        assert!(!s.contains(key), "no-policy run must not grow {key}");
    }
}
