//! Integration tests for the cluster subsystem — the acceptance properties:
//! link-byte conservation under pipelined sharding, and idealized scaling
//! monotonicity when the contention model is disabled.

use decoilfnet::accel::{FusionPlan, Weights};
use decoilfnet::cluster::{plan_fleet, run_fleet, simulate_fleet, ShardPlan};
use decoilfnet::config::{vgg16_prefix, AccelConfig, ClusterConfig, Network, ShardMode};

fn setup() -> (AccelConfig, Network, Weights) {
    let net = vgg16_prefix();
    let w = Weights::random(&net, 1);
    (AccelConfig::paper_default(), net, w)
}

/// Contention off, ideal links, batch=1, saturating burst: the regime where
/// scaling must be exactly monotone.
fn ideal_cfg(boards: usize, mode: ShardMode, requests: usize) -> ClusterConfig {
    ClusterConfig {
        boards,
        mode,
        link_bytes_per_cycle: f64::INFINITY,
        link_latency_cycles: 0,
        aggregate_ddr_bytes_per_cycle: None,
        arrival_rps: f64::INFINITY,
        requests,
        seed: 11,
        max_batch: 1,
        max_wait_us: 0.0,
    }
}

#[test]
fn pipelined_sharding_conserves_boundary_bytes() {
    // Acceptance (a): bytes crossing inter-board links equal the activation
    // volumes at the board cuts, computed independently from shape
    // inference — for every board count and several fusion plans.
    let (cfg, net, w) = setup();
    let shapes = net.shapes();
    let wb = cfg.platform.word_bytes;
    for plan in [
        FusionPlan::unfused(7),
        FusionPlan::from_group_sizes(7, &[2, 1, 2, 1, 1]).unwrap(),
        FusionPlan::from_group_sizes(7, &[3, 2, 2]).unwrap(),
    ] {
        for boards in 2..=8 {
            let sp = ShardPlan::pipelined(&cfg, &net, &w, &plan, boards);
            let expected: u64 = sp.shards[..sp.used_boards().saturating_sub(1)]
                .iter()
                .map(|s| (shapes[s.layers.end].elems() * wb) as u64)
                .sum();
            assert_eq!(
                sp.link_bytes_per_item(),
                expected,
                "plan {} boards {boards}",
                plan.label()
            );
            // And dynamically: the simulator moves exactly that per request.
            let ccfg = ideal_cfg(boards, ShardMode::Pipelined, 40);
            let r = simulate_fleet(&cfg, &sp, &ccfg);
            assert_eq!(r.link_bytes_total, expected * 40);
        }
    }
}

#[test]
fn replicated_throughput_monotone_without_contention() {
    // Acceptance (b), data-parallel half.
    let (cfg, net, w) = setup();
    let plan = FusionPlan::fully_fused(7);
    let mut last_makespan = u64::MAX;
    let mut last_tp = 0.0f64;
    for boards in 1..=12 {
        let sp = ShardPlan::replicated(&cfg, &net, &w, &plan, boards);
        let r = simulate_fleet(&cfg, &sp, &ideal_cfg(boards, ShardMode::Replicated, 120));
        assert!(
            r.makespan_cycles <= last_makespan,
            "boards {boards}: makespan rose {} > {last_makespan}",
            r.makespan_cycles
        );
        assert!(
            r.throughput_rps >= last_tp,
            "boards {boards}: throughput fell {} < {last_tp}",
            r.throughput_rps
        );
        last_makespan = r.makespan_cycles;
        last_tp = r.throughput_rps;
    }
}

#[test]
fn pipelined_throughput_monotone_without_contention() {
    // Acceptance (b), model-parallel half (ideal links isolate the
    // bandwidth question from link latency).
    let (cfg, net, w) = setup();
    let plan = FusionPlan::unfused(7);
    let mut last_makespan = u64::MAX;
    for boards in 1..=10 {
        let sp = ShardPlan::pipelined(&cfg, &net, &w, &plan, boards);
        let r = simulate_fleet(&cfg, &sp, &ideal_cfg(boards, ShardMode::Pipelined, 120));
        assert!(
            r.makespan_cycles <= last_makespan,
            "boards {boards}: makespan rose {} > {last_makespan}",
            r.makespan_cycles
        );
        last_makespan = r.makespan_cycles;
    }
}

#[test]
fn contention_only_ever_slows_the_fleet() {
    let (cfg, net, w) = setup();
    let plan = FusionPlan::unfused(7);
    for mode in [ShardMode::Replicated, ShardMode::Pipelined] {
        for boards in [2, 4, 8] {
            let sp = match mode {
                ShardMode::Replicated => ShardPlan::replicated(&cfg, &net, &w, &plan, boards),
                ShardMode::Pipelined => ShardPlan::pipelined(&cfg, &net, &w, &plan, boards),
            };
            let free = ideal_cfg(boards, mode, 60);
            let mut tight = free.clone();
            tight.aggregate_ddr_bytes_per_cycle = Some(cfg.platform.ddr_bytes_per_cycle);
            let r_free = simulate_fleet(&cfg, &sp, &free);
            let r_tight = simulate_fleet(&cfg, &sp, &tight);
            assert!(
                r_tight.throughput_rps <= r_free.throughput_rps,
                "{mode:?} {boards} boards"
            );
        }
    }
}

#[test]
fn fleet_from_json_config_end_to_end() {
    // The serving wiring: a ClusterConfig straight from JSON drives the
    // whole planner + scheduler stack.
    let (cfg, net, _) = setup();
    let ccfg = ClusterConfig::from_json_str(
        r#"{
            "boards": 6,
            "mode": "pipelined",
            "link_bytes_per_cycle": 32.0,
            "link_latency_cycles": 32,
            "aggregate_ddr_bytes_per_cycle": 256.0,
            "arrival_rps": 500.0,
            "requests": 48,
            "seed": 3,
            "max_batch": 4,
            "max_wait_us": 100.0
        }"#,
    )
    .unwrap();
    let r = run_fleet(&cfg, &net, &ccfg).unwrap();
    assert_eq!(r.completed, 48);
    assert!(r.used_boards >= 2 && r.used_boards <= 6);
    assert!(r.throughput_rps > 0.0);
    assert!(r.p99_ms >= r.p50_ms);
    let j = r.to_json();
    assert_eq!(j.get("mode").as_str(), Some("pipelined"));
    assert_eq!(j.get("completed").as_usize(), Some(48));
}

#[test]
fn pipelined_shards_respect_per_board_budget() {
    let (cfg, net, w) = setup();
    let mut ccfg = ClusterConfig::fleet_default();
    ccfg.mode = ShardMode::Pipelined;
    ccfg.boards = 5;
    let sp = plan_fleet(&cfg, &net, &w, &ccfg).unwrap();
    assert!(sp.fits());
    for s in &sp.shards {
        assert!(s.resources.dsp <= cfg.platform.dsp);
        assert!(s.resources.bram36() <= cfg.platform.bram36);
    }
}
