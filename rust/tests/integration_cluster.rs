//! Integration tests for the cluster subsystem — the acceptance properties:
//! link-byte conservation under pipelined sharding, idealized scaling
//! monotonicity when the contention model is disabled, per-board resource
//! feasibility on heterogeneous fleets, and the re-shard controller
//! recovering statically re-planned throughput after a traffic shift.

use decoilfnet::accel::latency::group_cost_estimate;
use decoilfnet::accel::{FusionPlan, Weights};
use decoilfnet::cluster::{
    balance_min_max, plan_fleet, run_fleet, simulate_fleet, simulate_fleet_dynamic,
    InterBoardLink, ShardPlan,
};
use decoilfnet::config::{
    vgg16_prefix, AccelConfig, ClusterConfig, LoadStep, Network, Platform, PreemptMode,
    ReshardPolicy, ShardMode,
};

fn setup() -> (AccelConfig, Network, Weights) {
    let net = vgg16_prefix();
    let w = Weights::random(&net, 1);
    (AccelConfig::paper_default(), net, w)
}

/// The older board generation: half the clock, half the DDR draw.
fn slow_gen(base: &AccelConfig) -> AccelConfig {
    AccelConfig {
        platform: Platform::virtex7_older_gen(),
        ..base.clone()
    }
}

/// Contention off, ideal links, batch=1, saturating burst: the regime where
/// scaling must be exactly monotone.
fn ideal_cfg(boards: usize, mode: ShardMode, requests: usize) -> ClusterConfig {
    ClusterConfig {
        boards,
        mode,
        board_specs: vec![],
        link_bytes_per_cycle: f64::INFINITY,
        link_latency_cycles: 0,
        aggregate_ddr_bytes_per_cycle: None,
        arrival_rps: f64::INFINITY,
        load_steps: vec![],
        requests,
        seed: 11,
        max_batch: 1,
        max_wait_us: 0.0,
        reshard: None,
        tenants: vec![],
        preempt_restart_cycles: 500,
        preempt_mode: PreemptMode::Restart,
        preempt_refill_cycles: 100,
        faults: None,
        fabric: None,
    }
}

#[test]
fn pipelined_sharding_conserves_boundary_bytes() {
    // Acceptance (a): bytes crossing inter-board links equal the activation
    // volumes at the board cuts, computed independently from shape
    // inference — for every board count and several fusion plans.
    let (cfg, net, w) = setup();
    let shapes = net.shapes();
    let wb = cfg.platform.word_bytes;
    for plan in [
        FusionPlan::unfused(7),
        FusionPlan::from_group_sizes(7, &[2, 1, 2, 1, 1]).unwrap(),
        FusionPlan::from_group_sizes(7, &[3, 2, 2]).unwrap(),
    ] {
        for boards in 2..=8 {
            let sp = ShardPlan::pipelined(&cfg, &net, &w, &plan, boards);
            let expected: u64 = sp.shards[..sp.used_boards().saturating_sub(1)]
                .iter()
                .map(|s| (shapes[s.layers.end].elems() * wb) as u64)
                .sum();
            assert_eq!(
                sp.link_bytes_per_item(),
                expected,
                "plan {} boards {boards}",
                plan.label()
            );
            // And dynamically: the simulator moves exactly that per request.
            let ccfg = ideal_cfg(boards, ShardMode::Pipelined, 40);
            let r = simulate_fleet(&cfg, &sp, &ccfg);
            assert_eq!(r.link_bytes_total, expected * 40);
        }
    }
}

#[test]
fn replicated_throughput_monotone_without_contention() {
    // Acceptance (b), data-parallel half.
    let (cfg, net, w) = setup();
    let plan = FusionPlan::fully_fused(7);
    let mut last_makespan = u64::MAX;
    let mut last_tp = 0.0f64;
    for boards in 1..=12 {
        let sp = ShardPlan::replicated(&cfg, &net, &w, &plan, boards);
        let r = simulate_fleet(&cfg, &sp, &ideal_cfg(boards, ShardMode::Replicated, 120));
        assert!(
            r.makespan_cycles <= last_makespan,
            "boards {boards}: makespan rose {} > {last_makespan}",
            r.makespan_cycles
        );
        assert!(
            r.throughput_rps >= last_tp,
            "boards {boards}: throughput fell {} < {last_tp}",
            r.throughput_rps
        );
        last_makespan = r.makespan_cycles;
        last_tp = r.throughput_rps;
    }
}

#[test]
fn pipelined_throughput_monotone_without_contention() {
    // Acceptance (b), model-parallel half (ideal links isolate the
    // bandwidth question from link latency).
    let (cfg, net, w) = setup();
    let plan = FusionPlan::unfused(7);
    let mut last_makespan = u64::MAX;
    for boards in 1..=10 {
        let sp = ShardPlan::pipelined(&cfg, &net, &w, &plan, boards);
        let r = simulate_fleet(&cfg, &sp, &ideal_cfg(boards, ShardMode::Pipelined, 120));
        assert!(
            r.makespan_cycles <= last_makespan,
            "boards {boards}: makespan rose {} > {last_makespan}",
            r.makespan_cycles
        );
        last_makespan = r.makespan_cycles;
    }
}

#[test]
fn contention_only_ever_slows_the_fleet() {
    let (cfg, net, w) = setup();
    let plan = FusionPlan::unfused(7);
    for mode in [ShardMode::Replicated, ShardMode::Pipelined] {
        for boards in [2, 4, 8] {
            let sp = match mode {
                ShardMode::Replicated => ShardPlan::replicated(&cfg, &net, &w, &plan, boards),
                ShardMode::Pipelined => ShardPlan::pipelined(&cfg, &net, &w, &plan, boards),
            };
            let free = ideal_cfg(boards, mode, 60);
            let mut tight = free.clone();
            tight.aggregate_ddr_bytes_per_cycle = Some(cfg.platform.ddr_bytes_per_cycle);
            let r_free = simulate_fleet(&cfg, &sp, &free);
            let r_tight = simulate_fleet(&cfg, &sp, &tight);
            assert!(
                r_tight.throughput_rps <= r_free.throughput_rps,
                "{mode:?} {boards} boards"
            );
        }
    }
}

#[test]
fn fleet_from_json_config_end_to_end() {
    // The serving wiring: a ClusterConfig straight from JSON drives the
    // whole planner + scheduler stack.
    let (cfg, net, _) = setup();
    let ccfg = ClusterConfig::from_json_str(
        r#"{
            "boards": 6,
            "mode": "pipelined",
            "link_bytes_per_cycle": 32.0,
            "link_latency_cycles": 32,
            "aggregate_ddr_bytes_per_cycle": 256.0,
            "arrival_rps": 500.0,
            "requests": 48,
            "seed": 3,
            "max_batch": 4,
            "max_wait_us": 100.0
        }"#,
    )
    .unwrap();
    let r = run_fleet(&cfg, &net, &ccfg).unwrap();
    assert_eq!(r.completed, 48);
    assert!(r.used_boards >= 2 && r.used_boards <= 6);
    assert!(r.throughput_rps > 0.0);
    assert!(r.p99_ms >= r.p50_ms);
    let j = r.to_json();
    assert_eq!(j.get("mode").as_str(), Some("pipelined"));
    assert_eq!(j.get("completed").as_usize(), Some(48));
}

#[test]
fn pipelined_shards_respect_per_board_budget() {
    let (cfg, net, w) = setup();
    let mut ccfg = ClusterConfig::fleet_default();
    ccfg.mode = ShardMode::Pipelined;
    ccfg.boards = 5;
    let sp = plan_fleet(&cfg, &net, &w, &ccfg).unwrap();
    assert!(sp.fits());
    for s in &sp.shards {
        assert!(s.resources.dsp <= cfg.platform.dsp);
        assert!(s.resources.bram36() <= cfg.platform.bram36);
    }
}

#[test]
fn hetero_pipelined_planner_respects_each_boards_own_budget() {
    // Acceptance: the heterogeneous pipelined planner never assigns a stage
    // that fails that board's own resource check. One mid-fleet board is
    // shrunk until it can only host the cheap layers; the DP must either
    // route around it or leave a provably infeasible board out — every
    // shard of a fitting plan passes the check of the *specific* board it
    // landed on.
    let (fast, net, w) = setup();
    let mut small = slow_gen(&fast);
    // 9·64-lane conv groups need 578 DSPs; 500 leaves room only for the
    // first conv (9·3 + 2 = 29) and the pools.
    small.platform.dsp = 500;
    small.platform.name = "small".to_string();
    let plan = FusionPlan::unfused(7);
    for fleet in [
        vec![fast.clone(), small.clone(), fast.clone()],
        vec![small.clone(), fast.clone(), fast.clone()],
        vec![fast.clone(), fast.clone(), small.clone(), fast.clone()],
    ] {
        let sp = ShardPlan::pipelined_fleet(&fleet, &net, &w, &plan);
        if sp.fits() {
            for s in &sp.shards {
                assert!(
                    s.resources.fits(&fleet[s.board]),
                    "stage {:?} on board {} ({}) exceeds that board's envelope",
                    s.layers,
                    s.board,
                    fleet[s.board].platform.name
                );
            }
        }
        // Whatever the DP decided, the fits flags must be truthful per
        // board, never checked against some other board's budget.
        for s in &sp.shards {
            assert_eq!(s.fits, s.resources.fits(&fleet[s.board]), "board {}", s.board);
        }
    }
}

#[test]
fn load_step_reshard_recovers_static_throughput() {
    // Acceptance: after a traffic shift, the re-shard controller recovers
    // ≥ 90% of the statically re-planned throughput. A two-generation fleet
    // starts on cuts balanced under a homogeneous assumption (the slow
    // boards become the bottleneck), traffic steps from 0.4× to 1.25× of
    // the naive plan's capacity, and the controller must migrate.
    let (cfg, net, w) = setup();
    let fleet = vec![cfg.clone(), cfg.clone(), slow_gen(&cfg), slow_gen(&cfg)];
    let plan = FusionPlan::unfused(7);

    // Naive cuts: min-max balance of raw cycles, blind to clocks.
    let totals: Vec<u64> = plan
        .groups()
        .iter()
        .map(|g| group_cost_estimate(&cfg, &net, g.clone()).total())
        .collect();
    let cuts = balance_min_max(&totals, fleet.len().min(totals.len()));
    let naive = ShardPlan::pipelined_fleet_with_cuts(&fleet, &net, &w, &plan, &cuts);

    let mut ccfg = ClusterConfig::fleet_default();
    ccfg.boards = 4;
    ccfg.mode = ShardMode::Pipelined;
    ccfg.aggregate_ddr_bytes_per_cycle = None;
    ccfg.requests = 512;
    ccfg.max_batch = 8;
    ccfg.seed = 3;
    let link = InterBoardLink::new(ccfg.link_bytes_per_cycle, ccfg.link_latency_cycles);
    let ref_freq = cfg.platform.freq_mhz;
    let naive_cap = naive.capacity_rps(ccfg.max_batch, &link, ref_freq);
    let naive_item_ms: f64 = naive.shards.iter().map(|s| s.item_us()).sum::<f64>() / 1e3;
    ccfg.arrival_rps = 0.4 * naive_cap;
    ccfg.load_steps = vec![LoadStep {
        at_request: 128,
        rps: 1.25 * naive_cap,
    }];

    // Statically re-planned baseline: the controller's own chooser at t=0.
    let static_best = [
        ShardPlan::replicated_fleet(&fleet, &net, &w, &plan),
        ShardPlan::pipelined_fleet(&fleet, &net, &w, &plan),
    ]
    .into_iter()
    .filter(|p| p.fits())
    .max_by(|a, b| {
        a.capacity_rps(ccfg.max_batch, &link, ref_freq)
            .partial_cmp(&b.capacity_rps(ccfg.max_batch, &link, ref_freq))
            .unwrap()
    })
    .expect("some plan fits the fleet");
    // The naive plan must genuinely be the inferior one, or the scenario
    // tests nothing.
    assert!(
        static_best.capacity_rps(ccfg.max_batch, &link, ref_freq) > naive_cap * 1.05,
        "static re-plan must beat naive capacity"
    );
    let r_static = simulate_fleet_dynamic(&cfg, &fleet, &net, &w, static_best.clone(), &ccfg);

    let mut dyn_cfg = ccfg.clone();
    dyn_cfg.reshard = Some(ReshardPolicy {
        window: 24,
        util_skew: 0.25,
        p99_ms: 2.5 * naive_item_ms,
        cooldown_windows: 1,
        migration_factor: 1.0,
    });
    let r_dyn = simulate_fleet_dynamic(&cfg, &fleet, &net, &w, naive.clone(), &dyn_cfg);

    assert!(
        !r_dyn.reshard_events.is_empty(),
        "the controller must migrate off the naive plan under load"
    );
    let e = &r_dyn.reshard_events[0];
    assert_eq!(e.from, naive.label());
    assert_ne!(e.to, naive.label());
    assert!(e.migration_bytes > 0, "weights must move");

    let recovery = r_dyn.throughput_rps / r_static.throughput_rps;
    assert!(
        recovery >= 0.9,
        "controller recovered only {recovery:.3} of statically re-planned \
         throughput ({:.1} vs {:.1} req/s)",
        r_dyn.throughput_rps,
        r_static.throughput_rps
    );

    // And the controller must actually have helped versus doing nothing.
    let r_frozen = simulate_fleet_dynamic(&cfg, &fleet, &net, &w, naive, &ccfg);
    assert!(
        r_dyn.throughput_rps >= r_frozen.throughput_rps * (1.0 - 1e-9),
        "re-sharding made things worse: {} vs frozen {}",
        r_dyn.throughput_rps,
        r_frozen.throughput_rps
    );
}

#[test]
fn hetero_fleet_from_json_end_to_end() {
    // Heterogeneous fleet + reshard policy straight from JSON through
    // `run_fleet`: planner uses each generation's envelope, report carries
    // idle-board accounting.
    let (cfg, net, _) = setup();
    let ccfg = ClusterConfig::from_json_str(
        r#"{
            "boards": 3,
            "mode": "pipelined",
            "board_specs": [
                {"count": 2, "platform": {"name": "Virtex-7 XC7V690T", "dsp": 3600,
                 "bram36": 1470, "lut": 433200, "ff": 866400, "freq_mhz": 120.0,
                 "ddr_bytes_per_cycle": 64.0, "word_bytes": 4}},
                {"count": 1, "platform": {"name": "Virtex-7 older", "dsp": 3600,
                 "bram36": 1470, "lut": 433200, "ff": 866400, "freq_mhz": 60.0,
                 "ddr_bytes_per_cycle": 32.0, "word_bytes": 4}}
            ],
            "arrival_rps": 200.0,
            "requests": 48,
            "seed": 5,
            "max_batch": 4,
            "reshard": {"window": 16, "util_skew": 0.5, "p99_ms": 500.0}
        }"#,
    )
    .unwrap();
    let r = run_fleet(&cfg, &net, &ccfg).unwrap();
    assert_eq!(r.completed, 48);
    assert!(r.throughput_rps > 0.0);
    let j = r.to_json();
    assert_eq!(
        j.get("idle_boards").as_usize(),
        Some(r.idle_boards),
        "idle boards must be surfaced in the report JSON"
    );
    assert_eq!(j.get("boards").as_usize(), Some(3));
}
