//! Property tests across module boundaries: random VGG-like networks through
//! the engine, the closed-form model, the planner and the resource model.

use decoilfnet::accel::latency::{plan_cycles_estimate, plan_traffic_bytes};
use decoilfnet::accel::{Engine, FusionPlan, Weights};
use decoilfnet::config::{AccelConfig, Layer, Network, VolShape};
use decoilfnet::coordinator::{best_plan, Objective};
use decoilfnet::resources::plan_resources;
use decoilfnet::util::prng::Rng;
use decoilfnet::util::prop::{check, PropConfig};

/// Generate a random small VGG-like network (3×3 convs + occasional pools).
fn random_net(r: &mut Rng) -> Network {
    let h = *[16usize, 20, 24, 32].get(r.range_usize(0, 3)).unwrap();
    let d = r.range_usize(1, 4);
    let n_layers = r.range_usize(2, 6);
    let mut layers = Vec::new();
    let mut cur_extent = h;
    for i in 0..n_layers {
        // Pools only while the map stays poolable; never as the first layer.
        if i > 0 && cur_extent >= 8 && r.chance(0.3) {
            layers.push(Layer::pool2x2(&format!("pool{i}")));
            cur_extent /= 2;
        } else {
            let filters = *[4usize, 8, 12, 16].get(r.range_usize(0, 3)).unwrap();
            layers.push(Layer::conv3x3(&format!("conv{i}"), filters));
        }
    }
    let net = Network {
        name: format!("rand-{h}x{h}x{d}-{n_layers}"),
        input: VolShape::new(h, h, d),
        layers,
    };
    net.validate().expect("generator must produce valid nets");
    net
}

fn cfg() -> AccelConfig {
    AccelConfig::paper_default()
}

#[test]
fn prop_closed_form_tracks_engine_on_random_nets() {
    let engine = Engine::new(cfg());
    check(
        "closed-form-vs-engine",
        PropConfig { cases: 40, seed: 0xF00D },
        |r| {
            let net = random_net(r);
            let n = net.layers.len();
            let plans = decoilfnet::accel::fusion::enumerate_plans(n);
            let plan = plans[r.range_usize(0, plans.len() - 1)].clone();
            (net, plan, r.next_u64())
        },
        |(net, plan, seed)| {
            let w = Weights::random(net, *seed);
            let sim = engine.simulate(net, &w, plan).total_cycles;
            let est = plan_cycles_estimate(&cfg(), net, plan);
            let err = (est as f64 - sim as f64).abs() / sim as f64;
            // Small nets are fill-dominated; the closed form is a planner
            // heuristic — bound it loosely but firmly.
            if err < 0.9 {
                Ok(())
            } else {
                Err(format!("{}: est {est} vs sim {sim} (err {err:.2})", net.name))
            }
        },
    );
}

#[test]
fn prop_traffic_exact_on_random_nets() {
    let engine = Engine::new(cfg());
    check(
        "traffic-exact",
        PropConfig { cases: 40, seed: 0xBEEF },
        |r| {
            let net = random_net(r);
            let n = net.layers.len();
            let plans = decoilfnet::accel::fusion::enumerate_plans(n);
            let plan = plans[r.range_usize(0, plans.len() - 1)].clone();
            (net, plan, r.next_u64())
        },
        |(net, plan, seed)| {
            let w = Weights::random(net, *seed);
            let sim = engine.simulate(net, &w, plan);
            let est = plan_traffic_bytes(&cfg(), net, &w, plan);
            if sim.ddr_read_bytes + sim.ddr_write_bytes == est {
                Ok(())
            } else {
                Err(format!(
                    "{} {}: engine {} vs formula {est}",
                    net.name,
                    plan.label(),
                    sim.ddr_read_bytes + sim.ddr_write_bytes
                ))
            }
        },
    );
}

#[test]
fn prop_fusion_never_increases_traffic_or_cycles() {
    let engine = Engine::new(cfg());
    check(
        "fusion-dominates",
        PropConfig { cases: 30, seed: 0xCAFE },
        |r| (random_net(r), r.next_u64()),
        |(net, seed)| {
            let n = net.layers.len();
            let w = Weights::random(net, *seed);
            let fused = engine.simulate(net, &w, &FusionPlan::fully_fused(n));
            let unfused = engine.simulate(net, &w, &FusionPlan::unfused(n));
            if fused.total_cycles > unfused.total_cycles {
                return Err(format!(
                    "{}: fused {} > unfused {} cycles",
                    net.name, fused.total_cycles, unfused.total_cycles
                ));
            }
            if fused.total_mb() > unfused.total_mb() + 1e-9 {
                return Err(format!("{}: fused moved more data", net.name));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_planner_winner_beats_extremes() {
    check(
        "planner-optimality",
        PropConfig { cases: 25, seed: 0xD00D },
        |r| (random_net(r), r.next_u64()),
        |(net, seed)| {
            let w = Weights::random(net, *seed);
            let n = net.layers.len();
            let best = best_plan(&cfg(), net, &w, Objective::Latency)
                .ok_or("no feasible plan".to_string())?;
            for candidate in [FusionPlan::fully_fused(n), FusionPlan::unfused(n)] {
                let res = plan_resources(&cfg(), net, &candidate);
                if res.fits(&cfg()) {
                    let est = plan_cycles_estimate(&cfg(), net, &candidate);
                    if best.cycles > est {
                        return Err(format!(
                            "winner {} ({}) worse than {} ({est})",
                            best.plan.label(),
                            best.cycles,
                            candidate.label()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_functional_output_in_relu_range_and_shape() {
    let engine = Engine::new(cfg());
    check(
        "forward-shape-range",
        PropConfig { cases: 12, seed: 0xAB },
        |r| (random_net(r), r.next_u64()),
        |(net, seed)| {
            let w = Weights::random(net, *seed);
            let input = decoilfnet::tensor::NdTensor::random(
                &net.input.as_slice(),
                *seed ^ 1,
                -1.0,
                1.0,
            );
            let out = engine.forward_fx(net, &w, &input);
            let want = net.shape_after(net.layers.len() - 1);
            if out.shape() != want.as_slice() {
                return Err(format!("shape {:?} vs {:?}", out.shape(), want));
            }
            if out.data().iter().any(|v| v.to_f32() < 0.0) {
                return Err("negative value after ReLU chain".to_string());
            }
            Ok(())
        },
    );
}
