//! Integration tests for multi-tenant fleet serving — the PR's acceptance
//! property: under a low-priority load spike, the high-priority tenant's
//! simulated p99 stays within its `SloPolicy` target (preemption cuts it
//! through the flood), the low-priority tenant absorbs the preemptions,
//! per-tenant item counts conserve, and the report JSON is deterministic
//! for a fixed seed. Plus the end-to-end JSON wiring: a `ClusterConfig`
//! with a `tenants` array drives planner + placement + simulator through
//! `run_fleet`.

use decoilfnet::accel::{FusionPlan, Weights};
use decoilfnet::cluster::{place_tenants, run_fleet, simulate_fleet_multi_tenant, TenantWorkload};
use decoilfnet::config::{
    tiny_vgg, AccelConfig, ClusterConfig, LoadStep, PreemptMode, ReshardPolicy, ShardMode,
    SloPolicy, TenantSpec,
};

/// Two tenants sharing one 2-board fleet: a high-priority interactive
/// stream with a tight SLO, and a low-priority bulk tenant whose traffic
/// spikes to a saturating burst mid-run.
fn spike_specs() -> Vec<TenantSpec> {
    vec![
        TenantSpec {
            name: "interactive".to_string(),
            network: tiny_vgg(),
            weights_seed: 1,
            arrival_rps: 1500.0,
            requests: 48,
            load_steps: vec![],
            mode: ShardMode::Replicated,
            replicas: None,
            slo: SloPolicy {
                p99_ms: 1.0,
                priority: 2,
                weight: 1.0,
                overload: None,
            },
        },
        TenantSpec {
            name: "bulk".to_string(),
            network: tiny_vgg(),
            weights_seed: 2,
            arrival_rps: 800.0,
            requests: 96,
            // The spike: from request 16 on, the remaining 80 requests
            // arrive at once.
            load_steps: vec![LoadStep {
                at_request: 16,
                rps: f64::INFINITY,
            }],
            mode: ShardMode::Replicated,
            replicas: None,
            slo: SloPolicy {
                p99_ms: 2.0,
                priority: 0,
                weight: 1.0,
                overload: None,
            },
        },
    ]
}

fn place(
    fleet: &[AccelConfig],
    specs: &[TenantSpec],
) -> (Vec<Weights>, Vec<decoilfnet::cluster::ShardPlan>) {
    let weights: Vec<Weights> = specs
        .iter()
        .map(|s| Weights::random(&s.network, s.weights_seed))
        .collect();
    let fused = FusionPlan::fully_fused(7);
    let workloads: Vec<TenantWorkload> = specs
        .iter()
        .zip(&weights)
        .map(|(s, w)| TenantWorkload {
            name: &s.name,
            net: &s.network,
            weights: w,
            plan: &fused,
            mode: s.mode,
            priority: s.slo.priority,
            replicas: s.replicas,
        })
        .collect();
    let plans = place_tenants(fleet, &workloads).unwrap();
    (weights, plans)
}

fn spike_cfg() -> ClusterConfig {
    let mut c = ClusterConfig::fleet_default();
    c.boards = 2;
    c.aggregate_ddr_bytes_per_cycle = None;
    c.link_bytes_per_cycle = f64::INFINITY;
    c.link_latency_cycles = 0;
    c.max_batch = 8;
    c.max_wait_us = 0.0;
    c.seed = 7;
    c.preempt_restart_cycles = 500;
    c
}

#[test]
fn load_spike_preemption_protects_high_priority_slo() {
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone()];
    let specs = spike_specs();
    let (w, plans) = place(&fleet, &specs);
    let ccfg = spike_cfg();
    let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &ccfg);

    let hi = &r.tenants[0];
    let lo = &r.tenants[1];

    // Conservation: every request served exactly once, on both sides, and
    // the per-board item counters agree with the totals.
    assert_eq!(hi.completed, 48);
    assert_eq!(lo.completed, 96);
    assert_eq!(hi.items, 48);
    assert_eq!(lo.items, 96);
    assert_eq!(r.requests, 144);
    assert_eq!(r.completed, 144);
    let board_items: u64 = r.per_board.iter().map(|b| b.items).sum();
    assert_eq!(board_items, 144, "no request lost or double-served");

    // The SLO story: the high-priority tenant rides through the spike
    // inside its target; the bulk tenant absorbs the preemptions.
    assert!(
        hi.slo_met,
        "interactive p99 {} ms must stay within its {} ms SLO",
        hi.p99_ms, hi.slo_p99_ms
    );
    assert_eq!(hi.preemptions, 0, "nobody outranks the interactive tenant");
    assert!(lo.preemptions > 0, "the bulk tenant must absorb preemptions");
    assert!(
        !lo.slo_met,
        "a tenant flooded past capacity cannot meet a 2 ms p99 (got {} ms)",
        lo.p99_ms
    );
    assert!(
        hi.p99_ms < lo.p99_ms / 10.0,
        "priority must separate the tails: hi {} ms vs lo {} ms",
        hi.p99_ms,
        lo.p99_ms
    );
}

#[test]
fn multi_tenant_report_json_is_deterministic_for_a_fixed_seed() {
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone()];
    let specs = spike_specs();
    let (w, plans) = place(&fleet, &specs);
    let ccfg = spike_cfg();
    let a = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &ccfg)
        .to_json()
        .to_string_pretty();
    let b = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &ccfg)
        .to_json()
        .to_string_pretty();
    assert_eq!(a, b, "fixed seed must give byte-identical report JSON");

    let mut reseeded = spike_cfg();
    reseeded.seed = 8;
    let c = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &reseeded)
        .to_json()
        .to_string_pretty();
    assert_ne!(a, c, "a different seed must sample different arrivals");
}

#[test]
fn tenants_json_drives_run_fleet_end_to_end() {
    // A full multi-tenant cluster config straight from JSON: two tiny
    // tenants, distinct priorities, per-tenant SLOs and a load step.
    let cfg = AccelConfig::paper_default();
    let net_json = tiny_vgg().to_json().to_string_compact();
    let text = format!(
        r#"{{
            "boards": 2,
            "mode": "replicated",
            "requests": 32,
            "seed": 9,
            "max_batch": 4,
            "max_wait_us": 0.0,
            "preempt_restart_cycles": 250,
            "tenants": [
                {{"name": "hi", "network": {net_json}, "weights_seed": 1,
                  "arrival_rps": 800.0, "requests": 20,
                  "slo": {{"p99_ms": 10.0, "priority": 3}}}},
                {{"name": "lo", "network": {net_json}, "weights_seed": 2,
                  "requests": 40,
                  "load_steps": [{{"at_request": 8}}],
                  "slo": {{"p99_ms": 4000.0, "priority": 1}}}}
            ]
        }}"#
    );
    let ccfg = ClusterConfig::from_json_str(&text).unwrap();
    assert_eq!(ccfg.tenants.len(), 2);
    assert!(ccfg.tenants[1].arrival_rps.is_infinite(), "burst by omission");
    assert!(ccfg.tenants[1].load_steps[0].rps.is_infinite());

    let r = run_fleet(&cfg, &tiny_vgg(), &ccfg).unwrap();
    assert_eq!(r.tenants.len(), 2);
    assert_eq!(r.completed, 60);
    assert_eq!(r.tenants[0].completed, 20);
    assert_eq!(r.tenants[1].completed, 40);
    let j = r.to_json();
    let tj = j.get("tenants");
    assert_eq!(tj.as_arr().unwrap().len(), 2);
    assert_eq!(tj.at(0).get("name").as_str(), Some("hi"));
    assert!(tj.at(0).get("p99_ms").as_f64().unwrap() > 0.0);
    assert!(tj.at(1).get("preemptions").as_u64().is_some());
    assert_eq!(
        tj.at(1).get("slo_p99_ms").as_f64(),
        Some(4000.0),
        "the SLO target is echoed in the report"
    );
}

// ---- preemption accounting (PreemptMode) ----

#[test]
fn resume_bills_strictly_fewer_cycles_than_restart_on_the_same_trace() {
    // Same seed, same arrivals, same placement — only the preempt mode
    // differs. Restart re-does every aborted batch in full; resume keeps
    // the finished prefixes and pays only the refill, so the fleet's total
    // billed cycles are strictly lower while every item still completes
    // exactly once on both sides.
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone()];
    let specs = spike_specs();
    let (w, plans) = place(&fleet, &specs);
    let restart_cfg = spike_cfg();
    assert_eq!(restart_cfg.preempt_mode, PreemptMode::Restart);
    let mut resume_cfg = spike_cfg();
    resume_cfg.preempt_mode = PreemptMode::Resume;
    resume_cfg.preempt_refill_cycles = 100;

    let ra = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &restart_cfg);
    let rb = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &resume_cfg);

    // Conservation across preempt/resume cycles, both modes.
    for (mode, r) in [("restart", &ra), ("resume", &rb)] {
        assert_eq!(r.tenants[0].completed, 48, "{mode}");
        assert_eq!(r.tenants[1].completed, 96, "{mode}");
        assert_eq!(r.tenants[0].items, 48, "{mode}");
        assert_eq!(r.tenants[1].items, 96, "{mode}");
        let board_items: u64 = r.per_board.iter().map(|b| b.items).sum();
        assert_eq!(board_items, 144, "{mode}: items conserve per board");
        assert!(r.tenants[1].preemptions > 0, "{mode}: spike must preempt");
        assert!(r.tenants[0].slo_met, "{mode}: hi SLO holds either way");
    }

    let billed = |r: &decoilfnet::cluster::FleetReport| {
        r.per_board.iter().map(|b| b.busy_cycles).sum::<u64>()
    };
    assert!(
        billed(&rb) < billed(&ra),
        "resume must bill strictly fewer total cycles: {} vs {}",
        billed(&rb),
        billed(&ra)
    );
    // The saved work shows up as an equal-or-better bulk tail.
    assert!(rb.tenants[1].p99_ms <= ra.tenants[1].p99_ms);
}

#[test]
fn restart_mode_reproduces_the_committed_spike_fixture_byte_for_byte() {
    // `PreemptMode::Restart` + no re-shard policy is the pre-unification
    // engine bit-for-bit; the committed golden fixture pins it. (The
    // fixture suite compares structurally at 1e-9; this is the stricter
    // bytes-equal form of the same guarantee.)
    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/multi_tenant_spike.json");
    let committed = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone()];
    let specs = spike_specs();
    let (w, plans) = place(&fleet, &specs);
    let ccfg = spike_cfg();
    assert_eq!(ccfg.preempt_mode, PreemptMode::Restart);
    let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &ccfg);
    assert_eq!(
        r.to_json().to_string_pretty() + "\n",
        committed,
        "restart mode must reproduce the committed fixture bytes"
    );
}

// ---- tenant-aware re-sharding (the unified control plane) ----

/// The load-step scenario the acceptance criterion names: a capped
/// interactive stream (one replica of two boards) whose rate doubles
/// mid-run past its board's capacity, over a low-priority bulk flood.
fn loadstep_specs(requests: usize, with_step: bool) -> Vec<TenantSpec> {
    vec![
        TenantSpec {
            name: "stream".to_string(),
            network: tiny_vgg(),
            weights_seed: 1,
            arrival_rps: 7500.0,
            requests,
            load_steps: if with_step {
                vec![LoadStep {
                    at_request: 96,
                    rps: 15000.0,
                }]
            } else {
                vec![]
            },
            mode: ShardMode::Replicated,
            replicas: Some(1),
            slo: SloPolicy {
                p99_ms: 0.5,
                priority: 2,
                weight: 1.0,
                overload: None,
            },
        },
        TenantSpec {
            name: "bulk".to_string(),
            network: tiny_vgg(),
            weights_seed: 2,
            arrival_rps: f64::INFINITY,
            requests: 64,
            load_steps: vec![],
            mode: ShardMode::Replicated,
            replicas: None,
            slo: SloPolicy {
                p99_ms: 5000.0,
                priority: 0,
                weight: 1.0,
                overload: None,
            },
        },
    ]
}

fn loadstep_cfg(reshard: bool) -> ClusterConfig {
    let mut c = spike_cfg();
    c.seed = 11;
    c.link_bytes_per_cycle = 16.0;
    c.link_latency_cycles = 64;
    c.reshard = if reshard {
        Some(ReshardPolicy {
            window: 48,
            util_skew: 0.9,
            p99_ms: 50.0, // superseded by per-tenant SLOs on this path
            cooldown_windows: 1,
            migration_factor: 1.0,
        })
    } else {
        None
    };
    c
}

#[test]
fn tenant_aware_reshard_recovers_post_step_p99() {
    // Acceptance criterion: under a load-step trace the unified engine's
    // post-reshard per-tenant p99 recovers to <= 1.1x its pre-step value,
    // while Resume bills measurably fewer cycles than Restart on the same
    // seed.
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone()];

    // Pre-step reference: the same seed and stream, truncated before the
    // step (arrivals 0..96 are bit-identical), controller armed but never
    // triggered.
    let ref_specs = loadstep_specs(96, false);
    let (ref_w, ref_plans) = place(&fleet, &ref_specs);
    let ref_ccfg = loadstep_cfg(true);
    let ref_run =
        simulate_fleet_multi_tenant(&cfg, &fleet, &ref_specs, &ref_w, &ref_plans, &ref_ccfg);
    assert!(
        ref_run.reshard_events.is_empty(),
        "the pre-step reference must not trigger: {:?}",
        ref_run.reshard_events
    );
    let pre_step_p99 = ref_run.tenants[0].p99_ms;

    // The stepped run: the stream's window p99 blows its SLO, the
    // controller uncaps it onto both boards, the tail recovers.
    let specs = loadstep_specs(320, true);
    let (w, plans) = place(&fleet, &specs);
    assert_eq!(
        plans[0].shards.iter().map(|s| s.board).collect::<Vec<_>>(),
        vec![0],
        "the replica cap pins the stream to one board pre-reshard"
    );
    let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &loadstep_cfg(true));
    assert!(
        !r.reshard_events.is_empty(),
        "the load step must trigger a tenant-aware re-shard"
    );
    for e in &r.reshard_events {
        assert_eq!(e.tenant.as_deref(), Some("stream"), "per-tenant event");
        assert!(e.reason.contains("slo"), "SLO trigger named: {}", e.reason);
        assert!(e.migration_bytes > 0, "scale-out moves weights");
        assert_eq!(e.from, "replicated:1");
        assert_eq!(e.to, "replicated:2");
    }
    let stream = &r.tenants[0];
    let tail = stream.tail_p99_ms.expect("armed controller reports the tail");
    assert!(
        tail <= 1.1 * pre_step_p99,
        "post-reshard p99 {tail:.4} ms must recover to <= 1.1x the pre-step \
         {pre_step_p99:.4} ms"
    );

    // Frozen baseline: same trace, controller off — the stream's tail
    // stays blown for the rest of the run.
    let frozen_ccfg = loadstep_cfg(false);
    let frozen = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &frozen_ccfg);
    assert!(frozen.reshard_events.is_empty());
    assert!(
        frozen.tenants[0].p99_ms > 2.0 * stream.p99_ms,
        "without re-sharding the stream tail must stay blown: frozen {} vs {}",
        frozen.tenants[0].p99_ms,
        stream.p99_ms
    );

    // And Resume bills measurably fewer cycles than Restart on this same
    // seed/trace (the flood preempts in both runs).
    let mut resume_cfg = loadstep_cfg(true);
    resume_cfg.preempt_mode = PreemptMode::Resume;
    let rr = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &resume_cfg);
    let billed = |r: &decoilfnet::cluster::FleetReport| {
        r.per_board.iter().map(|b| b.busy_cycles).sum::<u64>()
    };
    assert!(r.tenants[1].preemptions > 0);
    assert!(rr.tenants[1].preemptions > 0);
    assert!(
        billed(&rr) < billed(&r),
        "resume must bill fewer cycles on the load-step trace too: {} vs {}",
        billed(&rr),
        billed(&r)
    );
}

#[test]
fn mid_sim_replacement_is_deterministic_and_seed_sensitive() {
    // The controller's place_tenants re-runs are pure functions of the
    // observed state: the same seed replays byte-identically (re-shard
    // events included), a different seed samples a different trace.
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone()];
    let specs = loadstep_specs(320, true);
    let (w, plans) = place(&fleet, &specs);
    let a = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &loadstep_cfg(true));
    let b = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &loadstep_cfg(true));
    assert!(!a.reshard_events.is_empty());
    assert_eq!(
        a.to_json().to_string_pretty(),
        b.to_json().to_string_pretty(),
        "same seed must replay the re-sharding run byte-identically"
    );
    let mut other = loadstep_cfg(true);
    other.seed = 12;
    let c = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &other);
    assert_ne!(
        a.to_json().to_string_pretty(),
        c.to_json().to_string_pretty(),
        "a different seed must sample a different trace"
    );
}
