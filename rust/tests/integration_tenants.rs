//! Integration tests for multi-tenant fleet serving — the PR's acceptance
//! property: under a low-priority load spike, the high-priority tenant's
//! simulated p99 stays within its `SloPolicy` target (preemption cuts it
//! through the flood), the low-priority tenant absorbs the preemptions,
//! per-tenant item counts conserve, and the report JSON is deterministic
//! for a fixed seed. Plus the end-to-end JSON wiring: a `ClusterConfig`
//! with a `tenants` array drives planner + placement + simulator through
//! `run_fleet`.

use decoilfnet::accel::{FusionPlan, Weights};
use decoilfnet::cluster::{place_tenants, run_fleet, simulate_fleet_multi_tenant, TenantWorkload};
use decoilfnet::config::{
    tiny_vgg, AccelConfig, ClusterConfig, LoadStep, ShardMode, SloPolicy, TenantSpec,
};

/// Two tenants sharing one 2-board fleet: a high-priority interactive
/// stream with a tight SLO, and a low-priority bulk tenant whose traffic
/// spikes to a saturating burst mid-run.
fn spike_specs() -> Vec<TenantSpec> {
    vec![
        TenantSpec {
            name: "interactive".to_string(),
            network: tiny_vgg(),
            weights_seed: 1,
            arrival_rps: 1500.0,
            requests: 48,
            load_steps: vec![],
            mode: ShardMode::Replicated,
            replicas: None,
            slo: SloPolicy {
                p99_ms: 1.0,
                priority: 2,
            },
        },
        TenantSpec {
            name: "bulk".to_string(),
            network: tiny_vgg(),
            weights_seed: 2,
            arrival_rps: 800.0,
            requests: 96,
            // The spike: from request 16 on, the remaining 80 requests
            // arrive at once.
            load_steps: vec![LoadStep {
                at_request: 16,
                rps: f64::INFINITY,
            }],
            mode: ShardMode::Replicated,
            replicas: None,
            slo: SloPolicy {
                p99_ms: 2.0,
                priority: 0,
            },
        },
    ]
}

fn place(fleet: &[AccelConfig], specs: &[TenantSpec]) -> Vec<decoilfnet::cluster::ShardPlan> {
    let weights: Vec<Weights> = specs
        .iter()
        .map(|s| Weights::random(&s.network, s.weights_seed))
        .collect();
    let fused = FusionPlan::fully_fused(7);
    let workloads: Vec<TenantWorkload> = specs
        .iter()
        .zip(&weights)
        .map(|(s, w)| TenantWorkload {
            name: &s.name,
            net: &s.network,
            weights: w,
            plan: &fused,
            mode: s.mode,
            priority: s.slo.priority,
            replicas: s.replicas,
        })
        .collect();
    place_tenants(fleet, &workloads).unwrap()
}

fn spike_cfg() -> ClusterConfig {
    let mut c = ClusterConfig::fleet_default();
    c.boards = 2;
    c.aggregate_ddr_bytes_per_cycle = None;
    c.link_bytes_per_cycle = f64::INFINITY;
    c.link_latency_cycles = 0;
    c.max_batch = 8;
    c.max_wait_us = 0.0;
    c.seed = 7;
    c.preempt_restart_cycles = 500;
    c
}

#[test]
fn load_spike_preemption_protects_high_priority_slo() {
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone()];
    let specs = spike_specs();
    let plans = place(&fleet, &specs);
    let ccfg = spike_cfg();
    let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &plans, &ccfg);

    let hi = &r.tenants[0];
    let lo = &r.tenants[1];

    // Conservation: every request served exactly once, on both sides, and
    // the per-board item counters agree with the totals.
    assert_eq!(hi.completed, 48);
    assert_eq!(lo.completed, 96);
    assert_eq!(hi.items, 48);
    assert_eq!(lo.items, 96);
    assert_eq!(r.requests, 144);
    assert_eq!(r.completed, 144);
    let board_items: u64 = r.per_board.iter().map(|b| b.items).sum();
    assert_eq!(board_items, 144, "no request lost or double-served");

    // The SLO story: the high-priority tenant rides through the spike
    // inside its target; the bulk tenant absorbs the preemptions.
    assert!(
        hi.slo_met,
        "interactive p99 {} ms must stay within its {} ms SLO",
        hi.p99_ms, hi.slo_p99_ms
    );
    assert_eq!(hi.preemptions, 0, "nobody outranks the interactive tenant");
    assert!(lo.preemptions > 0, "the bulk tenant must absorb preemptions");
    assert!(
        !lo.slo_met,
        "a tenant flooded past capacity cannot meet a 2 ms p99 (got {} ms)",
        lo.p99_ms
    );
    assert!(
        hi.p99_ms < lo.p99_ms / 10.0,
        "priority must separate the tails: hi {} ms vs lo {} ms",
        hi.p99_ms,
        lo.p99_ms
    );
}

#[test]
fn multi_tenant_report_json_is_deterministic_for_a_fixed_seed() {
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone()];
    let specs = spike_specs();
    let plans = place(&fleet, &specs);
    let ccfg = spike_cfg();
    let a = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &plans, &ccfg)
        .to_json()
        .to_string_pretty();
    let b = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &plans, &ccfg)
        .to_json()
        .to_string_pretty();
    assert_eq!(a, b, "fixed seed must give byte-identical report JSON");

    let mut reseeded = spike_cfg();
    reseeded.seed = 8;
    let c = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &plans, &reseeded)
        .to_json()
        .to_string_pretty();
    assert_ne!(a, c, "a different seed must sample different arrivals");
}

#[test]
fn tenants_json_drives_run_fleet_end_to_end() {
    // A full multi-tenant cluster config straight from JSON: two tiny
    // tenants, distinct priorities, per-tenant SLOs and a load step.
    let cfg = AccelConfig::paper_default();
    let net_json = tiny_vgg().to_json().to_string_compact();
    let text = format!(
        r#"{{
            "boards": 2,
            "mode": "replicated",
            "requests": 32,
            "seed": 9,
            "max_batch": 4,
            "max_wait_us": 0.0,
            "preempt_restart_cycles": 250,
            "tenants": [
                {{"name": "hi", "network": {net_json}, "weights_seed": 1,
                  "arrival_rps": 800.0, "requests": 20,
                  "slo": {{"p99_ms": 10.0, "priority": 3}}}},
                {{"name": "lo", "network": {net_json}, "weights_seed": 2,
                  "requests": 40,
                  "load_steps": [{{"at_request": 8}}],
                  "slo": {{"p99_ms": 4000.0, "priority": 1}}}}
            ]
        }}"#
    );
    let ccfg = ClusterConfig::from_json_str(&text).unwrap();
    assert_eq!(ccfg.tenants.len(), 2);
    assert!(ccfg.tenants[1].arrival_rps.is_infinite(), "burst by omission");
    assert!(ccfg.tenants[1].load_steps[0].rps.is_infinite());

    let r = run_fleet(&cfg, &tiny_vgg(), &ccfg).unwrap();
    assert_eq!(r.tenants.len(), 2);
    assert_eq!(r.completed, 60);
    assert_eq!(r.tenants[0].completed, 20);
    assert_eq!(r.tenants[1].completed, 40);
    let j = r.to_json();
    let tj = j.get("tenants");
    assert_eq!(tj.as_arr().unwrap().len(), 2);
    assert_eq!(tj.at(0).get("name").as_str(), Some("hi"));
    assert!(tj.at(0).get("p99_ms").as_f64().unwrap() > 0.0);
    assert!(tj.at(1).get("preemptions").as_u64().is_some());
    assert_eq!(
        tj.at(1).get("slo_p99_ms").as_f64(),
        Some(4000.0),
        "the SLO target is echoed in the report"
    );
}
