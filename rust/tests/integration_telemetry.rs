//! Trace-consistency suite for the fleet telemetry layer.
//!
//! The telemetry contract has two halves, and this file pins both:
//!
//! * **Disabled is invisible** — running through a disabled
//!   [`TraceSink`] must leave the `FleetReport` byte-identical to the
//!   plain entry points (no `telemetry` key, same numbers to the bit), so
//!   every committed fixture under `tests/fixtures/` keeps validating the
//!   untraced path.
//! * **Enabled is exact** — the raw event trace is not a lossy log: the
//!   per-tenant aggregates recomputed from `Flush`/`Preempt` events must
//!   equal the report's (items and preemption counts exactly, throughput
//!   bit-for-bit, since the recompute replays the same f64 operations),
//!   and the per-tenant quantile sketches must land within 1% of the
//!   exact `percentile_sorted` tails the report carries. A 128-case
//!   randomized property drives both across preemption modes, load
//!   steps, priorities, and armed re-shard controllers.
//!
//! The golden trace fixture (`mt_trace_spike.json`) pins the full
//! `decoilfnet-fleet-trace/v1` document — the same shape `cluster
//! --trace out.json` writes — for the committed `multi_tenant_spike`
//! scenario. It self-seeds on its first toolchain-equipped run (disabled
//! on CI, where a missing fixture fails with commit instructions) and
//! regenerates under `DECOILFNET_UPDATE_FIXTURES=1`, like the report
//! fixtures in `integration_fixtures.rs`.

use std::path::PathBuf;

use decoilfnet::accel::{FusionPlan, Weights};
use decoilfnet::cluster::{
    fleet_dashboard, flushed_items_per_tenant, last_flush_per_tenant, place_tenants,
    preemptions_per_tenant, simulate_fleet_multi_tenant, simulate_fleet_multi_tenant_traced,
    ShardPlan, TenantWorkload, TraceSink,
};
use decoilfnet::config::{
    tiny_vgg, AccelConfig, ClusterConfig, LoadStep, PreemptMode, ReshardPolicy, ShardMode,
    SloPolicy, TenantSpec,
};
use decoilfnet::util::json::{parse, Json};
use decoilfnet::util::prop::{check, PropConfig};

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name)
}

/// Trace fixtures authored in a toolchain-less environment that may
/// self-seed on their first run — same allowlist discipline as
/// `integration_fixtures.rs`: only named files may seed, and never on CI.
const SEEDABLE_FIXTURES: &[&str] = &["mt_trace_spike.json"];

/// Structural fixture comparison (exact except floats at 1e-9 relative),
/// with the same seed/update/CI semantics as `integration_fixtures.rs`.
fn assert_matches_fixture(name: &str, actual: &Json) {
    let path = fixture_path(name);
    let update = std::env::var("DECOILFNET_UPDATE_FIXTURES").map(|v| v == "1") == Ok(true);
    if !update && !path.exists() && std::env::var_os("GITHUB_ACTIONS").is_some() {
        panic!(
            "fixture {name} is not committed (self-seeding is disabled on CI): \
             run `cargo test --test integration_telemetry` locally and commit \
             rust/tests/fixtures/{name}"
        );
    }
    if update || (!path.exists() && SEEDABLE_FIXTURES.contains(&name)) {
        std::fs::write(&path, actual.to_string_pretty() + "\n")
            .unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
        eprintln!(
            "{} fixture {name} — commit the generated file",
            if update { "regenerated" } else { "seeded" }
        );
        return;
    }
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading fixture {}: {e}", path.display()));
    let expected = parse(&text).unwrap_or_else(|e| panic!("parsing {}: {e}", path.display()));
    let mut diffs = Vec::new();
    diff_json("$", &expected, actual, &mut diffs);
    assert!(
        diffs.is_empty(),
        "trace diverged from fixture {name} at:\n  {}\n\
         (intentional model change? regenerate with \
         DECOILFNET_UPDATE_FIXTURES=1 and commit the diff)",
        diffs.join("\n  ")
    );
}

/// Structural comparison: exact except floats at 1e-9 relative tolerance.
fn diff_json(path: &str, want: &Json, got: &Json, out: &mut Vec<String>) {
    match (want, got) {
        (Json::Num(a), Json::Num(b)) => {
            let tol = 1e-9 * a.abs().max(b.abs()).max(1.0);
            if (a - b).abs() > tol {
                out.push(format!("{path}: {a} vs {b}"));
            }
        }
        (Json::Obj(a), Json::Obj(b)) => {
            for k in a.keys().chain(b.keys().filter(|k| !a.contains_key(*k))) {
                match (a.get(k), b.get(k)) {
                    (Some(x), Some(y)) => diff_json(&format!("{path}.{k}"), x, y, out),
                    (Some(_), None) => out.push(format!("{path}.{k}: missing from report")),
                    (None, Some(_)) => out.push(format!("{path}.{k}: not in fixture")),
                    (None, None) => unreachable!(),
                }
            }
        }
        (Json::Arr(a), Json::Arr(b)) => {
            if a.len() != b.len() {
                out.push(format!("{path}: array len {} vs {}", a.len(), b.len()));
            } else {
                for (i, (x, y)) in a.iter().zip(b).enumerate() {
                    diff_json(&format!("{path}[{i}]"), x, y, out);
                }
            }
        }
        (a, b) => {
            if a != b {
                out.push(format!("{path}: {a:?} vs {b:?}"));
            }
        }
    }
}

/// Fleet-level config with every workload knob explicit (the
/// `integration_fixtures.rs` idiom), multi-tenant shaped.
fn mt_cfg(max_batch: usize, seed: u64) -> ClusterConfig {
    let mut c = ClusterConfig::fleet_default();
    c.boards = 2;
    c.mode = ShardMode::Replicated;
    c.board_specs = vec![];
    c.link_bytes_per_cycle = f64::INFINITY;
    c.link_latency_cycles = 0;
    c.aggregate_ddr_bytes_per_cycle = None;
    c.arrival_rps = f64::INFINITY;
    c.load_steps = vec![];
    c.requests = 1;
    c.seed = seed;
    c.max_batch = max_batch;
    c.max_wait_us = 0.0;
    c.reshard = None;
    c.tenants = vec![];
    c.preempt_restart_cycles = 500;
    c
}

fn tenant(name: &str, seed: u64, rps: f64, requests: usize, priority: u8) -> TenantSpec {
    TenantSpec {
        name: name.to_string(),
        network: tiny_vgg(),
        weights_seed: seed,
        arrival_rps: rps,
        requests,
        load_steps: vec![],
        mode: ShardMode::Replicated,
        replicas: None,
        slo: SloPolicy {
            p99_ms: if priority > 0 { 1.0 } else { 2.0 },
            priority,
            weight: 1.0,
            overload: None,
        },
    }
}

/// The committed `multi_tenant_spike` scenario, bit-for-bit: interactive
/// tenant with a 1 ms SLO vs a bulk tenant spiking at request 16.
fn spike_specs() -> Vec<TenantSpec> {
    let mut bulk = tenant("bulk", 2, 800.0, 96, 0);
    bulk.load_steps = vec![LoadStep {
        at_request: 16,
        rps: f64::INFINITY,
    }];
    vec![tenant("interactive", 1, 1500.0, 48, 2), bulk]
}

/// Fully-fused placement of replicated tiny tenants.
fn place_mt(fleet: &[AccelConfig], specs: &[TenantSpec]) -> (Vec<Weights>, Vec<ShardPlan>) {
    let weights: Vec<Weights> = specs
        .iter()
        .map(|s| Weights::random(&s.network, s.weights_seed))
        .collect();
    let fused = FusionPlan::fully_fused(7);
    let workloads: Vec<TenantWorkload> = specs
        .iter()
        .zip(&weights)
        .map(|(s, w)| TenantWorkload {
            name: &s.name,
            net: &s.network,
            weights: w,
            plan: &fused,
            mode: s.mode,
            priority: s.slo.priority,
            replicas: s.replicas,
        })
        .collect();
    let plans = place_tenants(fleet, &workloads).unwrap();
    (weights, plans)
}

/// One randomized multi-tenant scenario for the consistency property.
#[derive(Debug)]
struct MtCase {
    hi_rps: f64,
    hi_requests: usize,
    hi_priority: u8,
    hi_capped: bool,
    lo_rps: f64,
    lo_requests: usize,
    lo_priority: u8,
    step_at: Option<usize>,
    resume: bool,
    reshard: bool,
    max_batch: usize,
    seed: u64,
}

/// Trace-recomputed aggregates must equal the report's on every scenario:
/// items and preemption counts exactly, throughput bit-for-bit, and the
/// online sketch within 1% of the exact sorted-percentile tail.
#[test]
fn prop_trace_recomputes_report_on_random_scenarios() {
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone()];
    let ns_per_cycle = 1e3 / cfg.platform.freq_mhz;
    check(
        "trace-recomputes-report",
        PropConfig { cases: 128, seed: 0x7E1E },
        |r| MtCase {
            hi_rps: [800.0, 1500.0, 3000.0, f64::INFINITY][r.range_usize(0, 3)],
            hi_requests: r.range_usize(16, 64),
            hi_priority: r.range_usize(0, 2) as u8,
            hi_capped: r.chance(0.3),
            lo_rps: [800.0, 2000.0, f64::INFINITY][r.range_usize(0, 2)],
            lo_requests: r.range_usize(16, 96),
            lo_priority: r.range_usize(0, 2) as u8,
            step_at: if r.chance(0.5) {
                Some(r.range_usize(4, 16))
            } else {
                None
            },
            resume: r.chance(0.5),
            reshard: r.chance(0.3),
            max_batch: r.range_usize(2, 8),
            seed: r.range_u64(1, 1u64 << 40),
        },
        |case| {
            let mut hi = tenant("hi", 1, case.hi_rps, case.hi_requests, case.hi_priority);
            if case.hi_capped {
                hi.replicas = Some(1);
            }
            let mut lo = tenant("lo", 2, case.lo_rps, case.lo_requests, case.lo_priority);
            if let Some(at) = case.step_at {
                lo.load_steps = vec![LoadStep {
                    at_request: at,
                    rps: f64::INFINITY,
                }];
            }
            let specs = vec![hi, lo];
            let (weights, plans) = place_mt(&fleet, &specs);
            let mut ccfg = mt_cfg(case.max_batch, case.seed);
            ccfg.preempt_mode = if case.resume {
                PreemptMode::Resume
            } else {
                PreemptMode::Restart
            };
            ccfg.preempt_refill_cycles = 100;
            // Arm the controller only over a capped tenant — the proven
            // unified-control-plane shape; un-triggered windows still land
            // `WindowRollup` events in the trace.
            if case.reshard && case.hi_capped {
                ccfg.reshard = Some(ReshardPolicy {
                    window: 32,
                    util_skew: 0.9,
                    p99_ms: 50.0,
                    cooldown_windows: 1,
                    migration_factor: 1.0,
                });
            }
            let mut sink = TraceSink::enabled();
            let r = simulate_fleet_multi_tenant_traced(
                &cfg, &fleet, &specs, &weights, &plans, &ccfg, &mut sink,
            );
            let nt = specs.len();
            let flushed = flushed_items_per_tenant(&sink.events, nt);
            let spans = last_flush_per_tenant(&sink.events, nt);
            let preempts = preemptions_per_tenant(&sink.events, nt);
            for (t, stats) in r.tenants.iter().enumerate() {
                if flushed[t] != stats.items {
                    return Err(format!(
                        "tenant {t}: flushed {} != items {}",
                        flushed[t], stats.items
                    ));
                }
                if flushed[t] as usize != stats.completed {
                    return Err(format!(
                        "tenant {t}: flushed {} != completed {} (conservation)",
                        flushed[t], stats.completed
                    ));
                }
                if preempts[t] != stats.preemptions {
                    return Err(format!(
                        "tenant {t}: trace preemptions {} != report {}",
                        preempts[t], stats.preemptions
                    ));
                }
                let span_s = spans[t] as f64 * ns_per_cycle / 1e9;
                let rps = if span_s > 0.0 {
                    stats.requests as f64 / span_s
                } else {
                    0.0
                };
                if rps.to_bits() != stats.throughput_rps.to_bits() {
                    return Err(format!(
                        "tenant {t}: recomputed throughput {rps} != report {}",
                        stats.throughput_rps
                    ));
                }
                if stats.completed > 0 {
                    let q = sink.sketches[t].quantile(99.0);
                    if (q - stats.p99_ms).abs() > 0.01 * stats.p99_ms {
                        return Err(format!(
                            "tenant {t}: sketch p99 {q} off exact {} by > 1%",
                            stats.p99_ms
                        ));
                    }
                }
            }
            let tel = r.telemetry.as_ref().expect("armed sink yields a summary");
            if tel.events_total != sink.events.len() as u64 {
                return Err(format!(
                    "summary events_total {} != trace len {}",
                    tel.events_total,
                    sink.events.len()
                ));
            }
            let total_preempts: u64 = r.tenants.iter().map(|t| t.preemptions).sum();
            if tel.preemptions != total_preempts {
                return Err(format!(
                    "summary preemptions {} != tenant sum {total_preempts}",
                    tel.preemptions
                ));
            }
            Ok(())
        },
    );
}

/// A disabled sink must be invisible: same report to the bit, no
/// `telemetry` key — the property that keeps every committed fixture
/// validating the untraced path.
#[test]
fn disabled_sink_leaves_the_report_byte_identical() {
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone()];
    let specs = spike_specs();
    let (weights, plans) = place_mt(&fleet, &specs);
    let ccfg = mt_cfg(8, 7);
    let plain = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &weights, &plans, &ccfg);
    let mut sink = TraceSink::enabled();
    let traced = simulate_fleet_multi_tenant_traced(
        &cfg, &fleet, &specs, &weights, &plans, &ccfg, &mut sink,
    );
    assert!(
        plain.to_json().get("telemetry").is_null(),
        "disabled runs must not grow a telemetry key"
    );
    // The traced report must differ from the plain one by exactly the
    // telemetry key; every other byte of the report is identical.
    let mut diffs = Vec::new();
    diff_json("$", &plain.to_json(), &traced.to_json(), &mut diffs);
    assert_eq!(diffs, vec!["$.telemetry: not in fixture".to_string()]);
}

/// The golden trace document — `decoilfnet-fleet-trace/v1`, the exact
/// shape the `cluster --trace out.json` CLI writes — for the committed
/// spike scenario.
#[test]
fn fixture_mt_trace_spike() {
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone()];
    let specs = spike_specs();
    let (weights, plans) = place_mt(&fleet, &specs);
    let ccfg = mt_cfg(8, 7);
    let mut sink = TraceSink::enabled();
    let r = simulate_fleet_multi_tenant_traced(
        &cfg, &fleet, &specs, &weights, &plans, &ccfg, &mut sink,
    );
    assert!(
        r.tenants[1].preemptions > 0,
        "the golden trace must exercise preemption"
    );
    let doc = Json::obj()
        .set("schema", "decoilfnet-fleet-trace/v1")
        .set("report", r.to_json())
        .set("trace", sink.to_json());
    assert_matches_fixture("mt_trace_spike.json", &doc);
}

/// Dashboard smoke: one lane per board, a reshard lane, and a preemption
/// marker somewhere on the spike scenario's timeline.
#[test]
fn dashboard_renders_one_lane_per_board() {
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone()];
    let specs = spike_specs();
    let (weights, plans) = place_mt(&fleet, &specs);
    let ccfg = mt_cfg(8, 7);
    let mut sink = TraceSink::enabled();
    let r = simulate_fleet_multi_tenant_traced(
        &cfg, &fleet, &specs, &weights, &plans, &ccfg, &mut sink,
    );
    let dash = fleet_dashboard(&sink, r.boards, r.makespan_cycles, 64);
    assert!(dash.contains("reshard |"), "reshard lane present:\n{dash}");
    assert!(dash.contains("board 0"), "board 0 lane present:\n{dash}");
    assert!(dash.contains("board 1"), "board 1 lane present:\n{dash}");
    assert!(dash.contains('P'), "preemptions must mark the lanes:\n{dash}");
    assert_eq!(dash.lines().count(), r.boards + 1, "one lane per board plus reshard");
}
