//! Weighted-fair-sharing property battery for the unified control plane.
//!
//! ≥256 randomized two-tenant scenarios per property, all with EQUAL
//! priority classes — the regime the old strict-FIFO admission handled
//! worst (tenant 0 drained completely while tenant 1 starved). The
//! deficit-weighted round-robin admission (`SloPolicy::weight`) must:
//!
//! * **track weights**: with work proportional to weight, both tenants
//!   finish together (within batch-quantization slack) and the throughput
//!   ratio tracks the weight ratio;
//! * **never starve an equal-class peer**: a small tenant finishes far
//!   before a co-resident 6×-larger one — under the old admission its span
//!   equaled the big tenant's (progress only after the big queue drained);
//! * **conserve work**: no board idles while same-class work is queued —
//!   operationalized as "each board's idle tail is at most two batch
//!   services" (after the queues drain, at most one in-flight batch
//!   remains anywhere).
//!
//! All scenarios run the full placement + simulation stack (tiny-vgg
//! tenants co-resident on every board, burst arrivals, no contention) and
//! are deterministic per generated case; failures replay from the reported
//! (seed, case index).

use decoilfnet::accel::{FusionPlan, Weights};
use decoilfnet::cluster::{place_tenants, simulate_fleet_multi_tenant, ShardPlan, TenantWorkload};
use decoilfnet::config::{
    tiny_vgg, AccelConfig, ClusterConfig, PreemptMode, ShardMode, SloPolicy, TenantSpec,
};
use decoilfnet::util::prng::Rng;
use decoilfnet::util::prop;

/// ≥256 randomized scenarios per property, per the issue's floor.
const FAIRNESS_CASES: usize = 256;

fn prop_cfg() -> prop::PropConfig {
    prop::PropConfig {
        cases: FAIRNESS_CASES,
        ..prop::PropConfig::default()
    }
}

#[derive(Debug, Clone, Copy)]
struct Case {
    boards: usize,
    max_batch: usize,
    w1: u32,
    w2: u32,
    /// Work unit: tenant t fires `weight_t * base` requests.
    base: usize,
    seed: u64,
}

fn burst_tenant(name: &str, requests: usize, weight: f64) -> TenantSpec {
    TenantSpec {
        name: name.to_string(),
        network: tiny_vgg(),
        weights_seed: 1,
        arrival_rps: f64::INFINITY,
        requests,
        load_steps: vec![],
        mode: ShardMode::Replicated,
        replicas: None,
        slo: SloPolicy {
            p99_ms: 1e9, // fairness scenarios measure shares, not SLOs
            priority: 1,
            weight,
            overload: None,
        },
    }
}

fn fairness_ccfg(boards: usize, max_batch: usize, seed: u64) -> ClusterConfig {
    let mut c = ClusterConfig::fleet_default();
    c.boards = boards;
    c.aggregate_ddr_bytes_per_cycle = None;
    c.link_bytes_per_cycle = f64::INFINITY;
    c.link_latency_cycles = 0;
    c.max_batch = max_batch;
    c.max_wait_us = 0.0;
    c.seed = seed;
    c.preempt_mode = PreemptMode::Restart;
    c
}

fn place(
    fleet: &[AccelConfig],
    specs: &[TenantSpec],
) -> (Vec<Weights>, Vec<ShardPlan>) {
    let weights: Vec<Weights> = specs
        .iter()
        .map(|s| Weights::random(&s.network, s.weights_seed))
        .collect();
    let fused = FusionPlan::fully_fused(7);
    let workloads: Vec<TenantWorkload> = specs
        .iter()
        .zip(&weights)
        .map(|(s, w)| TenantWorkload {
            name: &s.name,
            net: &s.network,
            weights: w,
            plan: &fused,
            mode: s.mode,
            priority: s.slo.priority,
            replicas: s.replicas,
        })
        .collect();
    let plans = place_tenants(fleet, &workloads).unwrap();
    (weights, plans)
}

/// Span (cycles to the tenant's last completion) recovered from the
/// reported throughput.
fn span_cycles(requests: usize, throughput_rps: f64, ref_freq_mhz: f64) -> f64 {
    requests as f64 / throughput_rps * ref_freq_mhz * 1e6
}

fn gen_case(r: &mut Rng) -> Case {
    Case {
        boards: r.range_usize(1, 3),
        max_batch: r.range_usize(1, 6),
        w1: r.range_u64(1, 4) as u32,
        w2: r.range_u64(1, 4) as u32,
        base: [16, 24, 32][r.below(3) as usize],
        seed: r.next_u64(),
    }
}

#[test]
fn weighted_share_tracks_slo_weights() {
    let cfg = AccelConfig::paper_default();
    prop::check("fairness-weighted-share", prop_cfg(), gen_case, |c| {
        let fleet = vec![cfg.clone(); c.boards];
        let (req1, req2) = (c.w1 as usize * c.base, c.w2 as usize * c.base);
        let specs = vec![
            burst_tenant("a", req1, c.w1 as f64),
            burst_tenant("b", req2, c.w2 as f64),
        ];
        let (w, plans) = place(&fleet, &specs);
        let ccfg = fairness_ccfg(c.boards, c.max_batch, c.seed);
        let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &ccfg);

        // Conservation, and equal classes never preempt each other.
        let (a, b) = (&r.tenants[0], &r.tenants[1]);
        if a.completed != req1 || b.completed != req2 {
            return Err(format!("lost work: {}/{req1} {}/{req2}", a.completed, b.completed));
        }
        if a.preemptions + b.preemptions != 0 {
            return Err("equal-class tenants preempted each other".to_string());
        }

        let ref_freq = cfg.platform.freq_mhz;
        let svc_mb = plans[0].shards[0].ref_cycles(c.max_batch as u64, ref_freq) as f64;

        // Proportional work finishes together, within batch quantization:
        // the lighter tenant's final batch can lag by up to the weight
        // ratio's worth of heavy batches, plus one in-flight batch per
        // board.
        let sa = span_cycles(req1, a.throughput_rps, ref_freq);
        let sb = span_cycles(req2, b.throughput_rps, ref_freq);
        let wr = (c.w1 as f64 / c.w2 as f64).max(c.w2 as f64 / c.w1 as f64);
        let slack = (c.boards as f64 + wr + 1.0) * svc_mb;
        if (sa - sb).abs() > slack {
            return Err(format!(
                "spans diverged beyond quantization: {sa:.0} vs {sb:.0} (slack {slack:.0})"
            ));
        }

        // Throughput ratio tracks the weight ratio.
        let want = c.w1 as f64 / c.w2 as f64;
        let got = a.throughput_rps / b.throughput_rps;
        if (got / want - 1.0).abs() > 0.4 {
            return Err(format!("throughput ratio {got:.3} vs weight ratio {want:.3}"));
        }

        // Work conservation: no board idles while same-class work queues.
        // Burst arrivals mean a board only goes idle once the queues are
        // empty, so its idle tail is bounded by the in-flight batches.
        for pb in &r.per_board {
            let idle = r.makespan_cycles.saturating_sub(pb.busy_cycles) as f64;
            if idle > 2.0 * svc_mb {
                return Err(format!(
                    "board {} idled {idle:.0} cycles (> 2 batch services {svc_mb:.0}) \
                     while work was queued",
                    pb.board
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn no_equal_class_tenant_starves() {
    // The regression the DRR admission exists for: equal class, equal
    // weights, a 6×-bigger burst at the LOWER tenant index. The old
    // strict-FIFO admission gave tenant 0 every board until its queue
    // drained, so the small tenant's span equaled the big one's; under
    // weighted fair sharing the small tenant makes progress every round
    // and finishes in well under 60% of the big span (ideal: ~2/7).
    let cfg = AccelConfig::paper_default();
    prop::check("fairness-no-starvation", prop_cfg(), gen_case, |c| {
        let fleet = vec![cfg.clone(); c.boards];
        let small_req = c.base;
        let big_req = 6 * c.base;
        let specs = vec![
            burst_tenant("big", big_req, 1.0),
            burst_tenant("small", small_req, 1.0),
        ];
        let (w, plans) = place(&fleet, &specs);
        let ccfg = fairness_ccfg(c.boards, c.max_batch, c.seed);
        let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &ccfg);
        let ref_freq = cfg.platform.freq_mhz;
        let big = span_cycles(big_req, r.tenants[0].throughput_rps, ref_freq);
        let small = span_cycles(small_req, r.tenants[1].throughput_rps, ref_freq);
        if small >= 0.6 * big {
            return Err(format!(
                "small tenant starved: span {small:.0} vs big {big:.0} \
                 (strict-FIFO admission would give ~1.0)"
            ));
        }
        Ok(())
    });
}
