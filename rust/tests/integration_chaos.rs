//! Chaos & fault-tolerance battery for the tenant-aware control plane.
//!
//! Every scenario here drives the multi-tenant simulator through a seeded,
//! deterministic [`FaultScript`] — board failures (single and overlapping
//! double outages, with recovery), link-degrade windows, and clock-derate
//! pairs — and holds the control plane to four properties:
//!
//! * **Item conservation** — every request of every tenant completes
//!   exactly once, outage or not (the engine's internal asserts are
//!   cross-checked against the report's measured counters).
//! * **No starvation of survivors** — tenants drained off a dead board
//!   keep serving on the surviving replicas; severed pipelined chains are
//!   emergency-re-sharded onto the live boards.
//! * **Bounded recovery** — once every scripted disturbance is over, the
//!   fleet-wide p99 of post-recovery completions returns to within 1.25×
//!   of the pre-fault p99. The battery's load is sized so this is
//!   structural, not statistical: ~0.076 erlangs offered to 3 boards means
//!   waiting is a ~7e-5-per-request event, far below the 1% rank slack of
//!   a p99 over hundreds of samples.
//! * **Telemetry ↔ report consistency** — the `FaultSummary` counters, the
//!   `TelemetrySummary` counters, and the raw fault-typed trace events all
//!   agree (including the per-event re-queue counts).
//!
//! The golden outage fixture (`chaos_outage_recovery.json`) pins the full
//! `decoilfnet-fleet-trace/v1` document for a fixed outage scene — a
//! pipelined chain severed mid-run, a link flap, a thermal derate pair —
//! byte-stable across runs, with the same self-seeding allowlist
//! discipline as the other fixture suites (never on CI).

use std::path::PathBuf;

use decoilfnet::accel::{FusionPlan, Weights};
use decoilfnet::cluster::{
    place_tenants, simulate_fleet_multi_tenant, simulate_fleet_multi_tenant_traced, ShardPlan,
    TenantWorkload, TraceEvent, TraceSink,
};
use decoilfnet::config::{
    tiny_vgg, AccelConfig, ClusterConfig, FaultEvent, FaultScript, PreemptMode, ReshardPolicy,
    ShardMode, SloPolicy, TenantSpec,
};
use decoilfnet::util::json::{parse, Json};
use decoilfnet::util::prop::{check, PropConfig};

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name)
}

/// Fixtures authored in a toolchain-less environment that may self-seed on
/// their first run — same allowlist discipline as `integration_fixtures.rs`:
/// only named files may seed, and never on CI.
const SEEDABLE_FIXTURES: &[&str] = &["chaos_outage_recovery.json"];

/// Structural fixture comparison (exact except floats at 1e-9 relative),
/// with the same seed/update/CI semantics as `integration_fixtures.rs`.
fn assert_matches_fixture(name: &str, actual: &Json) {
    let path = fixture_path(name);
    let update = std::env::var("DECOILFNET_UPDATE_FIXTURES").map(|v| v == "1") == Ok(true);
    if !update && !path.exists() && std::env::var_os("GITHUB_ACTIONS").is_some() {
        panic!(
            "fixture {name} is not committed (self-seeding is disabled on CI): \
             run `cargo test --test integration_chaos` locally and commit \
             rust/tests/fixtures/{name}"
        );
    }
    if update || (!path.exists() && SEEDABLE_FIXTURES.contains(&name)) {
        std::fs::write(&path, actual.to_string_pretty() + "\n")
            .unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
        eprintln!(
            "{} fixture {name} — commit the generated file",
            if update { "regenerated" } else { "seeded" }
        );
        return;
    }
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading fixture {}: {e}", path.display()));
    let expected = parse(&text).unwrap_or_else(|e| panic!("parsing {}: {e}", path.display()));
    let mut diffs = Vec::new();
    diff_json("$", &expected, actual, &mut diffs);
    assert!(
        diffs.is_empty(),
        "outage run diverged from fixture {name} at:\n  {}\n\
         (intentional model change? regenerate with \
         DECOILFNET_UPDATE_FIXTURES=1 and commit the diff)",
        diffs.join("\n  ")
    );
}

/// Structural comparison: exact except floats at 1e-9 relative tolerance.
fn diff_json(path: &str, want: &Json, got: &Json, out: &mut Vec<String>) {
    match (want, got) {
        (Json::Num(a), Json::Num(b)) => {
            let tol = 1e-9 * a.abs().max(b.abs()).max(1.0);
            if (a - b).abs() > tol {
                out.push(format!("{path}: {a} vs {b}"));
            }
        }
        (Json::Obj(a), Json::Obj(b)) => {
            for k in a.keys().chain(b.keys().filter(|k| !a.contains_key(*k))) {
                match (a.get(k), b.get(k)) {
                    (Some(x), Some(y)) => diff_json(&format!("{path}.{k}"), x, y, out),
                    (Some(_), None) => out.push(format!("{path}.{k}: missing from report")),
                    (None, Some(_)) => out.push(format!("{path}.{k}: not in fixture")),
                    (None, None) => unreachable!(),
                }
            }
        }
        (Json::Arr(a), Json::Arr(b)) => {
            if a.len() != b.len() {
                out.push(format!("{path}: array len {} vs {}", a.len(), b.len()));
            } else {
                for (i, (x, y)) in a.iter().zip(b).enumerate() {
                    diff_json(&format!("{path}[{i}]"), x, y, out);
                }
            }
        }
        (a, b) => {
            if a != b {
                out.push(format!("{path}: {a:?} vs {b:?}"));
            }
        }
    }
}

fn tenant(name: &str, seed: u64, rps: f64, requests: usize, mode: ShardMode) -> TenantSpec {
    TenantSpec {
        name: name.to_string(),
        network: tiny_vgg(),
        weights_seed: seed,
        arrival_rps: rps,
        requests,
        load_steps: vec![],
        mode,
        replicas: None,
        slo: SloPolicy {
            p99_ms: 5.0,
            priority: 1,
            weight: 1.0,
            overload: None,
        },
    }
}

/// Placement with per-mode fusion plans: replicated tenants fully fused,
/// pipelined tenants unfused (so the stage DP has cut points).
fn place_chaos(fleet: &[AccelConfig], specs: &[TenantSpec]) -> (Vec<Weights>, Vec<ShardPlan>) {
    let weights: Vec<Weights> = specs
        .iter()
        .map(|s| Weights::random(&s.network, s.weights_seed))
        .collect();
    let fused = FusionPlan::fully_fused(7);
    let unfused = FusionPlan::unfused(7);
    let workloads: Vec<TenantWorkload> = specs
        .iter()
        .zip(&weights)
        .map(|(s, w)| TenantWorkload {
            name: &s.name,
            net: &s.network,
            weights: w,
            plan: match s.mode {
                ShardMode::Replicated => &fused,
                ShardMode::Pipelined => &unfused,
            },
            mode: s.mode,
            priority: s.slo.priority,
            replicas: s.replicas,
        })
        .collect();
    let plans = place_tenants(fleet, &workloads).unwrap();
    (weights, plans)
}

/// The battery's fleet config: 3 homogeneous boards, work-preserving
/// preemption, and a re-shard controller armed with thresholds only the
/// recovery re-admission can trip (skew 0.9 and 5 ms tenant SLOs are
/// unreachable at ~8% utilization).
fn chaos_cfg(seed: u64, max_batch: usize) -> ClusterConfig {
    let mut c = ClusterConfig::fleet_default();
    c.boards = 3;
    c.mode = ShardMode::Replicated;
    c.board_specs = vec![];
    c.link_bytes_per_cycle = f64::INFINITY;
    c.link_latency_cycles = 0;
    c.aggregate_ddr_bytes_per_cycle = None;
    c.arrival_rps = f64::INFINITY;
    c.load_steps = vec![];
    c.requests = 1;
    c.seed = seed;
    c.max_batch = max_batch;
    c.max_wait_us = 0.0;
    c.reshard = Some(ReshardPolicy {
        window: 32,
        util_skew: 0.9,
        p99_ms: 50.0,
        cooldown_windows: 1,
        migration_factor: 0.0,
    });
    c.tenants = vec![];
    c.preempt_mode = PreemptMode::Resume;
    c.preempt_restart_cycles = 500;
    c.preempt_refill_cycles = 100;
    c
}

/// One randomized fault scenario: which board dies and when, whether a
/// second overlapping outage follows, and optional link/clock faults.
#[derive(Debug)]
struct ChaosCase {
    down_board: usize,
    double_outage: bool,
    fail_frac: f64,
    recover_frac: f64,
    link_fault: bool,
    derate: bool,
    max_batch: usize,
    seed: u64,
}

/// ≥ 64 seeded fault scripts through the battery properties: conservation,
/// survivor progress, bounded recovery, and three-way fault accounting
/// (trace events == telemetry counters == fault summary).
#[test]
fn prop_chaos_battery_survives_seeded_fault_scripts() {
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone(), cfg.clone()];
    // 2 tenants × 256 Poisson arrivals at 400 req/s each → ~640 ms span,
    // ~0.076 erlangs offered to 3 boards: pre-fault and post-recovery
    // completions are both effectively wait-free, which is what makes the
    // 1.25× recovery bound structural.
    const REQUESTS: usize = 256;
    const RPS: f64 = 400.0;
    let span_ms = REQUESTS as f64 / RPS * 1e3;
    check(
        "chaos-battery",
        PropConfig { cases: 64, seed: 0xC4A05 },
        |r| ChaosCase {
            down_board: r.range_usize(0, 2),
            double_outage: r.chance(0.3),
            fail_frac: 0.30 + 0.01 * r.range_usize(0, 8) as f64,
            recover_frac: 0.52 + 0.01 * r.range_usize(0, 8) as f64,
            link_fault: r.chance(0.5),
            derate: r.chance(0.5),
            max_batch: r.range_usize(2, 8),
            seed: r.range_u64(1, 1u64 << 40),
        },
        |case| {
            let specs = vec![
                tenant("alpha", 1, RPS, REQUESTS, ShardMode::Replicated),
                tenant("bravo", 2, RPS, REQUESTS, ShardMode::Replicated),
            ];
            let (weights, plans) = place_chaos(&fleet, &specs);
            let fail_at = span_ms * case.fail_frac;
            let recover_at = span_ms * case.recover_frac;
            let mut events = vec![FaultEvent::BoardDown {
                board: case.down_board,
                at_ms: fail_at,
                recover_ms: Some(recover_at),
            }];
            if case.double_outage {
                events.push(FaultEvent::BoardDown {
                    board: (case.down_board + 1) % 3,
                    at_ms: fail_at + 12.0,
                    recover_ms: Some(recover_at + 12.0),
                });
            }
            if case.link_fault {
                events.push(FaultEvent::LinkDegrade {
                    link: case.down_board,
                    factor: 0.5,
                    at_ms: fail_at + 3.0,
                    until_ms: recover_at,
                });
            }
            if case.derate {
                let db = (case.down_board + 2) % 3;
                events.push(FaultEvent::ClockDerate {
                    board: db,
                    factor: 0.8,
                    at_ms: fail_at + 5.0,
                });
                // Always restored before the recovery boundary closes.
                events.push(FaultEvent::ClockDerate {
                    board: db,
                    factor: 1.0,
                    at_ms: recover_at + 10.0,
                });
            }
            events.sort_by(|a, b| a.at_ms().partial_cmp(&b.at_ms()).unwrap());
            let mut ccfg = chaos_cfg(case.seed, case.max_batch);
            ccfg.tenants = specs.clone();
            ccfg.faults = Some(FaultScript { events });

            let mut sink = TraceSink::enabled();
            let r = simulate_fleet_multi_tenant_traced(
                &cfg, &fleet, &specs, &weights, &plans, &ccfg, &mut sink,
            );

            // Conservation + no starvation: every tenant finishes in full.
            for (t, stats) in r.tenants.iter().enumerate() {
                if stats.completed != REQUESTS || stats.items != REQUESTS as u64 {
                    return Err(format!(
                        "tenant {t}: completed {} / items {} != requests {REQUESTS}",
                        stats.completed, stats.items
                    ));
                }
                let attain = stats
                    .slo_attainment_outage
                    .ok_or_else(|| format!("tenant {t}: outage attainment missing"))?;
                if !(0.0..=1.0).contains(&attain) {
                    return Err(format!("tenant {t}: outage attainment {attain} out of range"));
                }
            }
            if r.completed != 2 * REQUESTS {
                return Err(format!("fleet completed {} != {}", r.completed, 2 * REQUESTS));
            }

            // Three-way fault accounting.
            let f = r.faults.as_ref().ok_or("faults summary missing")?;
            if f.board_failures < 1 {
                return Err("no board failure recorded".into());
            }
            let count = |kind: &str| -> u64 {
                sink.events.iter().filter(|e| e.kind() == kind).count() as u64
            };
            let tel = r.telemetry.as_ref().ok_or("telemetry summary missing")?;
            for (label, summary, telemetry, traced) in [
                ("board_failures", f.board_failures, tel.board_failures, count("board_fail")),
                (
                    "board_recoveries",
                    f.board_recoveries,
                    tel.board_recoveries,
                    count("board_recover"),
                ),
                ("link_degrades", f.link_degrades, tel.link_degrades, count("link_degrade")),
                (
                    "emergency_reshards",
                    f.emergency_reshards,
                    tel.emergency_reshards,
                    count("emergency_reshard"),
                ),
            ] {
                if summary != telemetry || summary != traced {
                    return Err(format!(
                        "{label}: summary {summary} / telemetry {telemetry} / trace {traced}"
                    ));
                }
            }
            let requeued_in_trace: u64 = sink
                .events
                .iter()
                .map(|ev| match ev {
                    TraceEvent::BoardFail { requeued, .. } => *requeued as u64,
                    _ => 0,
                })
                .sum();
            if f.items_requeued != requeued_in_trace {
                return Err(format!(
                    "items_requeued {} != trace sum {requeued_in_trace}",
                    f.items_requeued
                ));
            }

            // Bounded recovery: the post-recovery p99 returns to the
            // pre-fault baseline.
            let (pre, post) = match (f.pre_fault_p99_ms, f.recovery_p99_ms) {
                (Some(a), Some(b)) => (a, b),
                other => return Err(format!("pre/post p99 must both exist, got {other:?}")),
            };
            if post > 1.25 * pre {
                return Err(format!(
                    "recovery p99 {post:.4} ms > 1.25 × pre-fault p99 {pre:.4} ms"
                ));
            }
            Ok(())
        },
    );
}

/// The fixed outage scene behind the golden fixture: a pipelined chain's
/// entry-stage board dies mid-run and recovers, after a link flap on its
/// egress and around a thermal derate pair on a neighbor.
fn outage_scene(
    fleet: &[AccelConfig],
) -> (Vec<TenantSpec>, Vec<Weights>, Vec<ShardPlan>, ClusterConfig) {
    let specs = vec![
        tenant("alpha", 1, 800.0, 48, ShardMode::Replicated),
        tenant("beta", 2, 300.0, 32, ShardMode::Pipelined),
    ];
    let (weights, plans) = place_chaos(fleet, &specs);
    assert!(plans[1].used_boards() >= 2, "the chain must actually span boards");
    let chain_b0 = plans[1].shards[0].board;
    let derate_b = (chain_b0 + 1) % 3;
    let mut ccfg = chaos_cfg(11, 4);
    // Finite wire so the link flap bills real transfer time.
    ccfg.link_bytes_per_cycle = 16.0;
    ccfg.reshard = Some(ReshardPolicy {
        window: 16,
        util_skew: 0.9,
        p99_ms: 50.0,
        cooldown_windows: 1,
        migration_factor: 0.0,
    });
    ccfg.tenants = specs.clone();
    ccfg.faults = Some(FaultScript {
        events: vec![
            FaultEvent::LinkDegrade {
                link: chain_b0,
                factor: 0.5,
                at_ms: 5.0,
                until_ms: 20.0,
            },
            FaultEvent::BoardDown {
                board: chain_b0,
                at_ms: 30.0,
                recover_ms: Some(60.0),
            },
            FaultEvent::ClockDerate {
                board: derate_b,
                factor: 0.8,
                at_ms: 40.0,
            },
            FaultEvent::ClockDerate {
                board: derate_b,
                factor: 1.0,
                at_ms: 58.0,
            },
        ],
    });
    (specs, weights, plans, ccfg)
}

/// The golden outage document — `decoilfnet-fleet-trace/v1`, the exact
/// shape `cluster --faults script.json --trace out.json` writes — pinned
/// byte-stable: two in-process runs must agree to the byte, and the
/// committed fixture guards the values across toolchains.
#[test]
fn fixture_chaos_outage_recovery() {
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone(), cfg.clone()];
    let (specs, weights, plans, ccfg) = outage_scene(&fleet);
    let mut sink = TraceSink::enabled();
    let r = simulate_fleet_multi_tenant_traced(
        &cfg, &fleet, &specs, &weights, &plans, &ccfg, &mut sink,
    );
    let f = r.faults.as_ref().expect("script armed");
    assert_eq!(f.board_failures, 1);
    assert_eq!(f.board_recoveries, 1);
    assert_eq!(f.link_degrades, 1);
    assert_eq!(f.clock_derates, 2);
    assert!(
        f.emergency_reshards >= 1,
        "killing the chain's entry stage must force an emergency re-shard"
    );
    assert_eq!(r.completed, 48 + 32, "the outage loses nothing");
    let doc = Json::obj()
        .set("schema", "decoilfnet-fleet-trace/v1")
        .set("report", r.to_json())
        .set("trace", sink.to_json());

    // Byte-stability first: an identical in-process re-run must reproduce
    // the document exactly.
    let mut sink2 = TraceSink::enabled();
    let r2 = simulate_fleet_multi_tenant_traced(
        &cfg, &fleet, &specs, &weights, &plans, &ccfg, &mut sink2,
    );
    let doc2 = Json::obj()
        .set("schema", "decoilfnet-fleet-trace/v1")
        .set("report", r2.to_json())
        .set("trace", sink2.to_json());
    assert_eq!(
        doc.to_string_pretty(),
        doc2.to_string_pretty(),
        "outage runs must be byte-deterministic"
    );
    assert_matches_fixture("chaos_outage_recovery.json", &doc);
}

/// Faults are strictly opt-in: the same scene without a script reports no
/// fault keys at all — the invariant that keeps every previously committed
/// fixture byte-identical.
#[test]
fn no_script_means_no_fault_keys_anywhere() {
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone(), cfg.clone()];
    let (specs, weights, plans, mut ccfg) = outage_scene(&fleet);
    ccfg.faults = None;
    let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &weights, &plans, &ccfg);
    assert!(r.faults.is_none());
    let s = r.to_json().to_string_compact();
    assert!(!s.contains("\"faults\""));
    assert!(!s.contains("slo_attainment_outage"));
    assert!(!s.contains("board_fail"));
    // The graceful-degradation additions are equally opt-in: no overload
    // policy and no compute-degrade script means none of their keys either.
    for key in [
        "\"shed\"",
        "\"retried\"",
        "\"abandoned\"",
        "\"goodput_rps\"",
        "\"compute_degrades\"",
        "\"recovery_time_ms\"",
        "\"shed_total\"",
        "\"retried_total\"",
        "\"abandoned_total\"",
    ] {
        assert!(!s.contains(key), "script-free run must not grow {key}");
    }
}
