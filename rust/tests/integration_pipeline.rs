//! Cross-module integration: functional datapath ↔ CPU reference ↔ timing
//! engine ↔ baselines, on multiple networks and seeds (no artifacts needed).

use decoilfnet::accel::{Engine, FusionPlan, Weights};
use decoilfnet::baselines::cpu_ref::{self, CpuWeights};
use decoilfnet::baselines::{fused_layer, optimized};
use decoilfnet::config::{
    custom_4conv, paper_test_example, tiny_vgg, vgg16_prefix, AccelConfig, Network,
};
use decoilfnet::resources::plan_resources;
use decoilfnet::tensor::NdTensor;

fn engine() -> Engine {
    Engine::new(AccelConfig::paper_default())
}

/// The Q16.16 datapath must track the f32 CPU reference on every builtin
/// small network, across seeds.
#[test]
fn fixed_point_tracks_float_across_networks_and_seeds() {
    for net in [paper_test_example(), tiny_vgg()] {
        for seed in [1u64, 7, 42] {
            let wx = Weights::random(&net, seed);
            let wf = CpuWeights::random(&net, seed);
            let input = NdTensor::random(&net.input.as_slice(), seed ^ 0xABC, -1.0, 1.0);
            let fx = engine().forward_fx(&net, &wx, &input).to_f32();
            let cpu = cpu_ref::forward(&net, &wf, &input);
            let diff = fx.max_abs_diff(&cpu);
            assert!(
                diff < 2e-2,
                "{} seed {seed}: fixed vs float diff {diff}",
                net.name
            );
        }
    }
}

/// Random weights generated for the simulator and the CPU baseline from the
/// same seed must be numerically identical (they share the PRNG protocol).
#[test]
fn weight_generation_protocols_agree() {
    let net = tiny_vgg();
    let wx = Weights::random(&net, 33);
    let wf = CpuWeights::random(&net, 33);
    for (i, t) in wf.tensors.iter().enumerate() {
        match (t, &wx.banks[i]) {
            (None, None) => {}
            (Some((filt, bias)), Some(banks)) => {
                // Spot-check through the banked layout.
                let k = filt.shape()[0];
                let d = filt.shape()[3];
                for f in (0..k).step_by(3) {
                    for c in (0..d).step_by(2) {
                        let got = banks.tap(f, 4)[c].to_f32();
                        let want = filt.at4(f, 1, 1, c);
                        assert!(
                            (got - want).abs() < 2e-5,
                            "layer {i} filter {f} ch {c}: {got} vs {want}"
                        );
                    }
                    let b = banks.bias(f).to_f32();
                    assert!((b - bias.get(&[f])).abs() < 2e-5);
                }
            }
            _ => panic!("layer {i}: weight presence mismatch"),
        }
    }
}

/// Cycle counts must be invariant to the weight seed (timing is data-
/// independent) and deterministic across runs.
#[test]
fn timing_is_data_independent_and_deterministic() {
    let net = tiny_vgg();
    let e = engine();
    let plan = FusionPlan::fully_fused(7);
    let a = e.simulate(&net, &Weights::random(&net, 1), &plan);
    let b = e.simulate(&net, &Weights::random(&net, 999), &plan);
    assert_eq!(a.total_cycles, b.total_cycles);
    assert_eq!(a.ddr_read_bytes, b.ddr_read_bytes);
    let c = e.simulate(&net, &Weights::random(&net, 1), &plan);
    assert_eq!(a.total_cycles, c.total_cycles);
}

/// Every contiguous fusion plan computes the same function (movement, not
/// math) — checked end to end through the fixed-point forward.
#[test]
fn all_plans_same_function() {
    let net = paper_test_example();
    let w = Weights::random(&net, 5);
    let input = NdTensor::random(&net.input.as_slice(), 6, -1.0, 1.0);
    let e = engine();
    let reference = e.forward_fx(&net, &w, &input);
    // forward_fx is plan-independent by construction; simulate timing per
    // plan and confirm traffic ordering instead.
    let fused = e.simulate(&net, &w, &FusionPlan::fully_fused(3));
    let split = e.simulate(&net, &w, &FusionPlan::from_group_sizes(3, &[2, 1]).unwrap());
    let unfused = e.simulate(&net, &w, &FusionPlan::unfused(3));
    assert!(fused.total_mb() <= split.total_mb());
    assert!(split.total_mb() <= unfused.total_mb());
    assert!(fused.total_cycles <= split.total_cycles);
    assert!(split.total_cycles <= unfused.total_cycles);
    assert_eq!(reference.shape(), &net.shape_after(2).as_slice());
}

/// The headline comparison shape (E7): DeCoILFNet beats both baseline
/// accelerators by >2X cycles and [2] by ≫1X traffic on the VGG prefix.
#[test]
fn headline_shape_holds() {
    let cfg = AccelConfig::paper_default();
    let net = vgg16_prefix();
    let w = Weights::random(&net, 1);
    let ours = engine().simulate(&net, &w, &FusionPlan::fully_fused(7));
    let ocfg = optimized::OptimizedConfig::zhang2015();
    let opt = optimized::run(&ocfg, &cfg, &net);
    let fus = fused_layer::run(&ocfg, &cfg, &net, 28);

    assert!(opt.total_cycles as f64 / ours.total_cycles as f64 > 2.0);
    assert!(fus.total_cycles as f64 / ours.total_cycles as f64 > 2.0);
    assert!(opt.total_mb() / ours.total_mb() > 5.0);
    // [3] moves no more than ~the same order as us (paper: 3.64 vs 6.69).
    assert!(fus.total_mb() / ours.total_mb() < 1.5);
}

/// The paper's "speedup grows with fused depth" trend (Table II narrative).
#[test]
fn speedup_grows_with_depth_custom4() {
    let cfg = AccelConfig::paper_default();
    let full = custom_4conv();
    let e = engine();
    let mut per_prefix = Vec::new();
    for i in 0..4 {
        let prefix = Network {
            name: format!("p{i}"),
            input: full.input,
            layers: full.layers[..=i].to_vec(),
        };
        let w = Weights::random(&prefix, 1);
        let rep = e.simulate(&prefix, &w, &FusionPlan::fully_fused(i + 1));
        // CPU work grows ~linearly in conv count; sim time stays ~flat, so
        // work/sim-cycles must grow.
        let macs = prefix.total_macs() as f64;
        per_prefix.push(macs / rep.total_cycles as f64);
    }
    for w in per_prefix.windows(2) {
        assert!(w[1] > w[0], "throughput must grow with fusion: {per_prefix:?}");
    }
    let _ = cfg;
}

/// Resource model consistency: a plan's resources dominate each of its
/// groups' layers; unfused uses the max single layer.
#[test]
fn resource_composition() {
    let cfg = AccelConfig::paper_default();
    let net = vgg16_prefix();
    let fused = plan_resources(&cfg, &net, &FusionPlan::fully_fused(7));
    let unfused = plan_resources(&cfg, &net, &FusionPlan::unfused(7));
    assert!(fused.dsp > unfused.dsp);
    assert!(fused.fits(&cfg) && unfused.fits(&cfg));
}

/// Failure injection: malformed network specs are rejected everywhere.
#[test]
fn malformed_specs_rejected() {
    let bad = r#"{"name":"x","input":{"h":0,"w":8,"d":3},"layers":[
        {"type":"conv","name":"c","kernel":3,"filters":4,"stride":1,"padding":1,"relu":true}]}"#;
    assert!(Network::from_json_str(bad).is_err());
    let bad2 = r#"{"name":"x","input":{"h":8,"w":8,"d":3},"layers":[]}"#;
    assert!(Network::from_json_str(bad2).is_err());
    assert!(FusionPlan::from_group_sizes(7, &[4, 4]).is_err());
}
