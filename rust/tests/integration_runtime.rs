//! Artifact-dependent integration: the full python→rust bridge plus the
//! serving stack under concurrency and fault injection. All tests skip (with
//! a notice) when `make artifacts` has not been run.

use std::path::PathBuf;
use std::time::Duration;

use decoilfnet::config::AccelConfig;
use decoilfnet::coordinator::{BatchPolicy, Server, ServerConfig};
use decoilfnet::runtime::Runtime;
use decoilfnet::tensor::NdTensor;
use decoilfnet::util::prng::Rng;
use decoilfnet::verify::{verify_all, verify_plan, DEFAULT_TOLERANCE};

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        None
    }
}

#[test]
fn every_network_every_plan_matches_golden() {
    let Some(dir) = artifacts() else { return };
    for name in ["paper-example", "tiny-vgg"] {
        let rt = Runtime::load(&dir, name).unwrap();
        let (input, want) = rt.golden().unwrap();
        for plan in rt.plan_names() {
            let got = rt.plan(plan).unwrap().run(&input).unwrap();
            let diff = got.max_abs_diff(&want);
            assert!(diff < 1e-3, "{name}/{plan}: {diff}");
        }
    }
}

#[test]
fn simulator_agrees_on_random_inputs_not_just_golden() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::load(&dir, "tiny-vgg").unwrap();
    let cfg = AccelConfig::paper_default();
    let mut rng = Rng::new(321);
    for _ in 0..3 {
        let mut input = NdTensor::zeros(&rt.entry.network.input.as_slice());
        rng.fill_f32(input.data_mut(), -2.0, 2.0);
        let rep = verify_plan(&rt, &cfg, "fused", &input, DEFAULT_TOLERANCE).unwrap();
        assert!(rep.passed, "diff {} > {}", rep.max_abs_diff, rep.tolerance);
    }
}

#[test]
fn group_chaining_boundaries_are_consistent() {
    // The unfused plan's group boundaries must match the network shapes, and
    // chaining through run_traced must reproduce the single-shot output.
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::load(&dir, "tiny-vgg").unwrap();
    let (input, _) = rt.golden().unwrap();
    let plan = rt.plan("unfused").unwrap();
    let traced = plan.run_traced(&input).unwrap();
    assert_eq!(traced.len(), 7);
    for (i, out) in traced.iter().enumerate() {
        let want = rt.entry.network.shape_after(i);
        assert_eq!(out.shape(), &want.as_slice(), "layer {i} boundary shape");
    }
    let single = rt.plan("fused").unwrap().run(&input).unwrap();
    assert!(traced.last().unwrap().max_abs_diff(&single) < 1e-3);
}

#[test]
fn verify_all_passes_for_all_networks() {
    let Some(dir) = artifacts() else { return };
    let cfg = AccelConfig::paper_default();
    for name in ["paper-example", "tiny-vgg"] {
        let rt = Runtime::load(&dir, name).unwrap();
        for rep in verify_all(&rt, &cfg).unwrap() {
            assert!(rep.passed, "{name}/{}: {}", rep.plan, rep.max_abs_diff);
        }
    }
}

#[test]
fn server_survives_mixed_valid_and_invalid_traffic() {
    let Some(dir) = artifacts() else { return };
    let srv = Server::start(ServerConfig {
        artifacts_dir: dir.clone(),
        network: "tiny-vgg".into(),
        default_plan: "fused".into(),
        batch: BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
        },
    })
    .unwrap();
    let rt = Runtime::load(&dir, "tiny-vgg").unwrap();
    let (input, want) = rt.golden().unwrap();

    let mut joins = Vec::new();
    for c in 0..3 {
        let h = srv.handle.clone();
        let input = input.clone();
        let want = want.clone();
        joins.push(std::thread::spawn(move || {
            for r in 0..6 {
                match (c + r) % 3 {
                    0 => {
                        // valid request
                        let resp = h.submit(input.clone(), None).wait().unwrap();
                        assert!(resp.result.unwrap().max_abs_diff(&want) < 1e-3);
                    }
                    1 => {
                        // wrong shape → error response, not a crash
                        let bad = NdTensor::zeros(&[4, 4, 3]);
                        let resp = h.submit(bad, None).wait().unwrap();
                        assert!(resp.result.is_err());
                    }
                    _ => {
                        // unknown plan → error response
                        let resp = h.submit(input.clone(), Some("nope")).wait().unwrap();
                        assert!(resp.result.is_err());
                    }
                }
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let m = srv.handle.metrics();
    assert_eq!(m.requests, 18);
    assert_eq!(m.responses + m.errors, 18);
    assert_eq!(m.errors, 12);
    srv.shutdown();
}

#[test]
fn latency_metrics_populated_under_load() {
    let Some(dir) = artifacts() else { return };
    let srv = Server::start(ServerConfig {
        artifacts_dir: dir.clone(),
        network: "paper-example".into(),
        default_plan: "fused".into(),
        batch: BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
        },
    })
    .unwrap();
    let rt = Runtime::load(&dir, "paper-example").unwrap();
    let (input, _) = rt.golden().unwrap();
    for _ in 0..10 {
        srv.handle.submit(input.clone(), None).wait().unwrap();
    }
    let m = srv.handle.metrics();
    let s = m.latency_summary().expect("latencies recorded");
    assert_eq!(s.n, 10);
    assert!(s.median > 0.0);
    assert!(m.mean_batch_size() >= 1.0);
    let json = srv.handle.metrics_json();
    assert!(json.contains("latency_p50_ms"));
    srv.shutdown();
}
