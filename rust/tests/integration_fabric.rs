//! Interconnect-fabric battery: routed topologies, shared-segment
//! contention, topology-aware placement, and the link-accounting
//! conservation laws — seeded, deterministic, replayable per case.
//!
//! Properties held:
//!
//! * **Per-segment byte conservation** — across randomized topologies
//!   (rack ring / leaf-spine, varying racks and uplink widths), replaying
//!   every `route_transfer` trace event through `Fabric::route` reproduces
//!   each segment's byte odometer exactly, and the telemetry `route_bytes`
//!   counter equals the report's `link_bytes_total` (the static scheduler
//!   bills only boundary traffic).
//! * **Serialized lower bound** — two pipelined chains whose boundaries
//!   share one rack uplink finish no earlier than the uplink can drain
//!   their combined bytes; and the same chain placed cross-rack is
//!   measurably slower than in-rack at identical payload.
//! * **No-residue** — the report of a fabric-armed run differs from the
//!   `fabric: None` run of the same scene by exactly the new keys (the
//!   `fabric` section and the `route_*` telemetry counters); the flat
//!   report loses nothing.
//! * **Conservation across re-shard** — a board failure mid-transfer
//!   forces an emergency re-shard; the fabric's segment odometers still
//!   replay exactly from the route events (nothing is reset by the link
//!   rebuild), and every request completes.
//! * **Rack-scoped faults** — `rack_down` expands to correlated
//!   board-down events over the rack's members; a replicated tenant whose
//!   replicas the topology-aware planner spread across racks survives on
//!   the other rack.
//!
//! The golden fixture (`fabric_uplink_contention.json`) pins the full
//! `decoilfnet-fleet-trace/v1` document for the shared-uplink scene, with
//! the same self-seeding allowlist discipline as the other fixture suites
//! (never on CI).

use std::collections::BTreeSet;
use std::path::PathBuf;

use decoilfnet::accel::{FusionPlan, Weights};
use decoilfnet::cluster::{
    place_tenants, place_tenants_capacity_fabric, simulate_fleet_multi_tenant_traced,
    simulate_fleet_traced, Fabric, ShardPlan, TenantWorkload, TraceEvent, TraceSink,
};
use decoilfnet::config::{
    tiny_vgg, AccelConfig, ClusterConfig, FabricSpec, FabricTopology, FaultEvent, FaultScript,
    PreemptMode, ReshardPolicy, ShardMode, SloPolicy, TenantSpec,
};
use decoilfnet::util::json::{parse, Json};
use decoilfnet::util::prop::{check, PropConfig};

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name)
}

/// Fixtures authored in a toolchain-less environment that may self-seed on
/// their first run — same allowlist discipline as `integration_fixtures.rs`:
/// only named files may seed, and never on CI.
const SEEDABLE_FIXTURES: &[&str] = &["fabric_uplink_contention.json"];

/// Structural fixture comparison (exact except floats at 1e-9 relative),
/// with the same seed/update/CI semantics as `integration_fixtures.rs`.
fn assert_matches_fixture(name: &str, actual: &Json) {
    let path = fixture_path(name);
    let update = std::env::var("DECOILFNET_UPDATE_FIXTURES").map(|v| v == "1") == Ok(true);
    if !update && !path.exists() && std::env::var_os("GITHUB_ACTIONS").is_some() {
        panic!(
            "fixture {name} is not committed (self-seeding is disabled on CI): \
             run `cargo test --test integration_fabric` locally and commit \
             rust/tests/fixtures/{name}"
        );
    }
    if update || (!path.exists() && SEEDABLE_FIXTURES.contains(&name)) {
        std::fs::write(&path, actual.to_string_pretty() + "\n")
            .unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
        eprintln!(
            "{} fixture {name} — commit the generated file",
            if update { "regenerated" } else { "seeded" }
        );
        return;
    }
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading fixture {}: {e}", path.display()));
    let expected = parse(&text).unwrap_or_else(|e| panic!("parsing {}: {e}", path.display()));
    let mut diffs = Vec::new();
    diff_json("$", &expected, actual, &mut diffs);
    assert!(
        diffs.is_empty(),
        "fabric run diverged from fixture {name} at:\n  {}\n\
         (intentional model change? regenerate with \
         DECOILFNET_UPDATE_FIXTURES=1 and commit the diff)",
        diffs.join("\n  ")
    );
}

/// Structural comparison: exact except floats at 1e-9 relative tolerance.
fn diff_json(path: &str, want: &Json, got: &Json, out: &mut Vec<String>) {
    match (want, got) {
        (Json::Num(a), Json::Num(b)) => {
            let tol = 1e-9 * a.abs().max(b.abs()).max(1.0);
            if (a - b).abs() > tol {
                out.push(format!("{path}: {a} vs {b}"));
            }
        }
        (Json::Obj(a), Json::Obj(b)) => {
            for k in a.keys().chain(b.keys().filter(|k| !a.contains_key(*k))) {
                match (a.get(k), b.get(k)) {
                    (Some(x), Some(y)) => diff_json(&format!("{path}.{k}"), x, y, out),
                    (Some(_), None) => out.push(format!("{path}.{k}: missing from report")),
                    (None, Some(_)) => out.push(format!("{path}.{k}: not in fixture")),
                    (None, None) => unreachable!(),
                }
            }
        }
        (Json::Arr(a), Json::Arr(b)) => {
            if a.len() != b.len() {
                out.push(format!("{path}: array len {} vs {}", a.len(), b.len()));
            } else {
                for (i, (x, y)) in a.iter().zip(b).enumerate() {
                    diff_json(&format!("{path}[{i}]"), x, y, out);
                }
            }
        }
        (a, b) => {
            if a != b {
                out.push(format!("{path}: {a:?} vs {b:?}"));
            }
        }
    }
}

/// Every object key path of a JSON document (array elements share their
/// parent's `[]` path — fixture-stable regardless of array lengths).
fn key_paths(j: &Json, prefix: &str, out: &mut BTreeSet<String>) {
    match j {
        Json::Obj(m) => {
            for k in m.keys() {
                let p = format!("{prefix}.{k}");
                key_paths(m.get(k).unwrap(), &p, out);
                out.insert(p);
            }
        }
        Json::Arr(a) => {
            for x in a {
                key_paths(x, &format!("{prefix}[]"), out);
            }
        }
        _ => {}
    }
}

/// Replay every `route_transfer` event through a freshly built router and
/// return (per-segment expected bytes, total event bytes). The sim billed
/// the real fabric; if its odometers differ from this replay, bytes were
/// lost or invented somewhere — e.g. by a re-shard rebuilding state.
fn replay_routes(
    spec: &FabricSpec,
    boards: usize,
    events: &[TraceEvent],
) -> Result<(Vec<u64>, u64), String> {
    let fab = Fabric::new(spec, boards);
    let mut per_seg = vec![0u64; fab.segments.len()];
    let mut total = 0u64;
    for ev in events {
        if let TraceEvent::RouteTransfer {
            src, dst, bytes, hops, ..
        } = ev
        {
            let route = fab.route(*src, *dst);
            if route.len() != *hops {
                return Err(format!(
                    "route_transfer {src}->{dst} recorded {hops} hops, router says {}",
                    route.len()
                ));
            }
            for &s in &route {
                per_seg[s] += *bytes;
            }
            total += *bytes;
        }
    }
    Ok((per_seg, total))
}

fn segments_match(
    report: &decoilfnet::cluster::FleetReport,
    per_seg: &[u64],
) -> Result<(), String> {
    let fs = report.fabric.as_ref().ok_or("report is missing the fabric section")?;
    if fs.segments.len() != per_seg.len() {
        return Err(format!(
            "segment count {} != router's {}",
            fs.segments.len(),
            per_seg.len()
        ));
    }
    for (i, s) in fs.segments.iter().enumerate() {
        if s.bytes_moved != per_seg[i] {
            return Err(format!(
                "segment {i} ({}): odometer {} diverged from the route replay's {}",
                s.name, s.bytes_moved, per_seg[i]
            ));
        }
    }
    Ok(())
}

fn pipelined_cfg(boards: usize, requests: usize, seed: u64) -> ClusterConfig {
    let mut c = ClusterConfig::fleet_default();
    c.boards = boards;
    c.mode = ShardMode::Pipelined;
    c.board_specs = vec![];
    c.link_bytes_per_cycle = 16.0;
    c.link_latency_cycles = 64;
    c.aggregate_ddr_bytes_per_cycle = None;
    c.arrival_rps = f64::INFINITY;
    c.load_steps = vec![];
    c.requests = requests;
    c.seed = seed;
    c.max_batch = 4;
    c.max_wait_us = 0.0;
    c.reshard = None;
    c.tenants = vec![];
    c
}

#[derive(Debug)]
struct FabricCase {
    boards: usize,
    boards_per_rack: usize,
    ring: bool,
    uplink_bpc: f64,
    requests: usize,
    seed: u64,
}

/// ≥ 64 randomized topologies: the static pipelined scheduler's fabric
/// odometers replay exactly from the trace, and the telemetry counters
/// agree with the report's boundary-byte total.
#[test]
fn prop_per_segment_bytes_conserve_across_topologies() {
    let cfg = AccelConfig::paper_default();
    let net = tiny_vgg();
    let weights = Weights::random(&net, 1);
    let plan = FusionPlan::unfused(7);
    check(
        "fabric-conservation-battery",
        PropConfig { cases: 64, seed: 0xFAB0C0DE },
        |r| FabricCase {
            boards: r.range_usize(2, 4),
            boards_per_rack: r.range_usize(1, 4),
            ring: r.below(2) == 1,
            uplink_bpc: [1.0, 2.0, 4.0][r.below(3) as usize],
            requests: r.range_usize(8, 32),
            seed: r.range_u64(1, 1u64 << 40),
        },
        |case| {
            let spec = FabricSpec {
                topology: if case.ring {
                    FabricTopology::RackRing
                } else {
                    FabricTopology::LeafSpine
                },
                uplink_bytes_per_cycle: case.uplink_bpc,
                ..FabricSpec::leaf_spine(case.boards_per_rack)
            };
            let shard = ShardPlan::pipelined(&cfg, &net, &weights, &plan, case.boards);
            let mut ccfg = pipelined_cfg(case.boards, case.requests, case.seed);
            ccfg.fabric = Some(spec.clone());
            let mut sink = TraceSink::enabled();
            let r = simulate_fleet_traced(&cfg, &shard, &ccfg, &mut sink);
            if r.completed != case.requests {
                return Err(format!("{}/{} requests completed", r.completed, case.requests));
            }

            let (per_seg, total) = replay_routes(&spec, case.boards, &sink.events)?;
            segments_match(&r, &per_seg)?;
            // The static scheduler routes boundary traffic only, so the
            // event total IS the link-byte ledger, and telemetry agrees.
            if total != r.link_bytes_total {
                return Err(format!(
                    "route events carried {total} B but the boundary ledger says {}",
                    r.link_bytes_total
                ));
            }
            let tel = r.telemetry.as_ref().ok_or("armed sink missing from report")?;
            if tel.route_bytes != Some(total) {
                return Err(format!(
                    "telemetry route_bytes {:?} != event total {total}",
                    tel.route_bytes
                ));
            }
            if tel.route_transfers.map(|n| n > 0) != Some(total > 0) {
                return Err(format!(
                    "route_transfers {:?} inconsistent with {total} B moved",
                    tel.route_transfers
                ));
            }
            Ok(())
        },
    );
}

/// The acceptance scene: the same 2-stage chain at identical payload is
/// measurably slower split across two racks than inside one, and the
/// cross-rack run's makespan respects the uplink's serialized drain bound.
#[test]
fn cross_rack_chain_is_slower_than_in_rack_at_equal_payload() {
    let cfg = AccelConfig::paper_default();
    let net = tiny_vgg();
    let weights = Weights::random(&net, 1);
    let plan = FusionPlan::unfused(7);
    let shard = ShardPlan::pipelined(&cfg, &net, &weights, &plan, 2);
    let mut ccfg = pipelined_cfg(2, 64, 9);

    // Both boards in one rack: boundary traffic rides the backplane only.
    ccfg.fabric = Some(FabricSpec::leaf_spine(2));
    let r_in = simulate_fleet_traced(&cfg, &shard, &ccfg, &mut TraceSink::disabled());
    let in_sum = r_in.fabric.as_ref().unwrap();
    assert!(
        in_sum.segments.iter().all(|s| s.kind != "uplink" || s.bytes_moved == 0),
        "an in-rack chain must not touch an uplink"
    );

    // One board per rack, a thin uplink: every boundary crosses four
    // segments and serializes on both racks' uplinks.
    let thin = FabricSpec {
        uplink_bytes_per_cycle: 1.0,
        ..FabricSpec::leaf_spine(1)
    };
    ccfg.fabric = Some(thin.clone());
    let r_x = simulate_fleet_traced(&cfg, &shard, &ccfg, &mut TraceSink::disabled());

    assert_eq!(
        r_in.link_bytes_total, r_x.link_bytes_total,
        "the placement moves the route, not the payload"
    );
    assert!(
        r_x.makespan_cycles > r_in.makespan_cycles,
        "cross-rack ({}) must be slower than in-rack ({})",
        r_x.makespan_cycles,
        r_in.makespan_cycles
    );
    // Serialized lower bound: a segment cannot drain faster than its
    // bandwidth, and it can only be busy while the run is live.
    let xs = r_x.fabric.as_ref().unwrap();
    for s in xs.segments.iter().filter(|s| s.kind == "uplink") {
        assert_eq!(s.bytes_moved, r_x.link_bytes_total, "1 board/rack: all traffic crosses");
        let drain = (s.bytes_moved as f64 / thin.uplink_bytes_per_cycle) as u64;
        assert!(
            r_x.makespan_cycles >= drain,
            "makespan {} beats the uplink's serialized drain {}",
            r_x.makespan_cycles,
            drain
        );
        assert!(s.busy_cycles >= drain, "busy time under-counts serialization");
        assert!(s.busy_cycles <= r_x.makespan_cycles, "busy time exceeds the run");
    }
}

/// Two pipelined tenants co-resident on a 2-board, 2-rack fleet: both
/// chains' boundary traffic shares the same uplinks, so the fleet cannot
/// finish before the shared wire drains the combined bytes. Pins the
/// golden shared-uplink contention fixture.
#[test]
fn two_chains_sharing_an_uplink_respect_the_serialized_bound() {
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone()];
    let tenant = |name: &str, seed: u64| TenantSpec {
        name: name.to_string(),
        network: tiny_vgg(),
        weights_seed: seed,
        arrival_rps: f64::INFINITY,
        requests: 48,
        load_steps: vec![],
        mode: ShardMode::Pipelined,
        replicas: None,
        slo: SloPolicy {
            p99_ms: 5000.0,
            priority: 1,
            weight: 1.0,
            overload: None,
        },
    };
    let specs = vec![tenant("alpha", 1), tenant("bravo", 2)];
    let weights: Vec<Weights> = specs
        .iter()
        .map(|s| Weights::random(&s.network, s.weights_seed))
        .collect();
    let unfused = FusionPlan::unfused(7);
    let workloads: Vec<TenantWorkload> = specs
        .iter()
        .zip(&weights)
        .map(|(s, w)| TenantWorkload {
            name: &s.name,
            net: &s.network,
            weights: w,
            plan: &unfused,
            mode: s.mode,
            priority: s.slo.priority,
            replicas: s.replicas,
        })
        .collect();
    let plans = place_tenants(&fleet, &workloads).expect("both chains fit");
    let spec = FabricSpec {
        uplink_bytes_per_cycle: 2.0,
        ..FabricSpec::leaf_spine(1)
    };
    let mut ccfg = pipelined_cfg(2, 1, 11);
    ccfg.tenants = specs.clone();
    ccfg.preempt_mode = PreemptMode::Resume;
    ccfg.fabric = Some(spec.clone());
    let mut sink = TraceSink::enabled();
    let r = simulate_fleet_multi_tenant_traced(
        &cfg, &fleet, &specs, &weights, &plans, &ccfg, &mut sink,
    );
    assert_eq!(r.completed, 96, "both tenants complete in full");

    let (per_seg, total) = replay_routes(&spec, 2, &sink.events).unwrap();
    segments_match(&r, &per_seg).unwrap();
    assert!(total > 0, "two chains must generate boundary traffic");
    let fs = r.fabric.as_ref().unwrap();
    for s in fs.segments.iter().filter(|s| s.kind == "uplink") {
        // Both tenants' bytes cross this wire; the fleet cannot finish
        // before it drains them back to back.
        assert_eq!(s.bytes_moved, total, "shared uplink carries both chains");
        let drain = (s.bytes_moved as f64 / spec.uplink_bytes_per_cycle) as u64;
        assert!(
            r.makespan_cycles >= drain,
            "makespan {} beats the shared uplink's serialized drain {}",
            r.makespan_cycles,
            drain
        );
    }

    let doc = Json::obj()
        .set("schema", "decoilfnet-fleet-trace/v1")
        .set("report", r.to_json())
        .set("trace", sink.to_json());
    assert_matches_fixture("fabric_uplink_contention.json", &doc);
}

/// The no-residue contract, stated as an exact key diff: arming a fabric
/// adds the `fabric` section and the `route_*` telemetry counters and
/// NOTHING else, and removes nothing.
#[test]
fn fabric_armed_report_diff_is_exactly_the_new_keys() {
    let cfg = AccelConfig::paper_default();
    let net = tiny_vgg();
    let weights = Weights::random(&net, 1);
    let plan = FusionPlan::unfused(7);
    let shard = ShardPlan::pipelined(&cfg, &net, &weights, &plan, 2);
    let mut ccfg = pipelined_cfg(2, 32, 5);

    let mut flat_sink = TraceSink::enabled();
    let flat = simulate_fleet_traced(&cfg, &shard, &ccfg, &mut flat_sink);
    ccfg.fabric = Some(FabricSpec::leaf_spine(1));
    let mut armed_sink = TraceSink::enabled();
    let armed = simulate_fleet_traced(&cfg, &shard, &ccfg, &mut armed_sink);

    let (mut fk, mut ak) = (BTreeSet::new(), BTreeSet::new());
    key_paths(&flat.to_json(), "$", &mut fk);
    key_paths(&armed.to_json(), "$", &mut ak);
    let lost: Vec<&String> = fk.difference(&ak).collect();
    assert!(lost.is_empty(), "arming the fabric must lose no keys: {lost:?}");
    let new: Vec<&String> = ak.difference(&fk).collect();
    assert!(!new.is_empty(), "an armed pipelined run must add keys");
    for k in &new {
        assert!(
            k.starts_with("$.fabric") || k.starts_with("$.telemetry.route_"),
            "unexpected new key {k}: the fabric must be additive-by-omission"
        );
    }
    for must in ["$.fabric", "$.telemetry.route_bytes", "$.telemetry.route_transfers"] {
        assert!(
            new.iter().any(|k| k.as_str() == must),
            "expected new key {must} missing"
        );
    }
    // And the flat report has no trace of the feature at all.
    let s = flat.to_json().to_string_compact();
    for key in ["\"fabric\"", "route_transfers", "route_bytes", "route_hops_max"] {
        assert!(!s.contains(key), "flat run must not grow {key}");
    }
}

/// Satellite regression for the re-shard link-state reset: a board failure
/// mid-transfer severs a pipelined chain, the emergency re-shard rebuilds
/// the plan's links — and the fabric's odometers still replay exactly from
/// the route events. Before the carry fix, rebuilt channels forgot their
/// occupancy and byte counts whenever a re-plan SUCCEEDED.
#[test]
fn emergency_reshard_mid_transfer_conserves_fabric_bytes() {
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone(), cfg.clone()];
    let specs = vec![TenantSpec {
        name: "chain".to_string(),
        network: tiny_vgg(),
        weights_seed: 1,
        arrival_rps: 400.0,
        requests: 256,
        load_steps: vec![],
        mode: ShardMode::Pipelined,
        replicas: None,
        slo: SloPolicy {
            p99_ms: 50.0,
            priority: 1,
            weight: 1.0,
            overload: None,
        },
    }];
    let weights: Vec<Weights> = specs
        .iter()
        .map(|s| Weights::random(&s.network, s.weights_seed))
        .collect();
    let unfused = FusionPlan::unfused(7);
    let workloads: Vec<TenantWorkload> = specs
        .iter()
        .zip(&weights)
        .map(|(s, w)| TenantWorkload {
            name: &s.name,
            net: &s.network,
            weights: w,
            plan: &unfused,
            mode: s.mode,
            priority: s.slo.priority,
            replicas: s.replicas,
        })
        .collect();
    let plans = place_tenants(&fleet, &workloads).expect("chain fits");
    assert!(plans[0].shards.len() >= 2, "a chain with real boundaries");
    let spec = FabricSpec::leaf_spine(3); // one rack: 1-hop routes
    let mut ccfg = pipelined_cfg(3, 1, 13);
    ccfg.tenants = specs.clone();
    ccfg.preempt_mode = PreemptMode::Resume;
    ccfg.reshard = Some(ReshardPolicy {
        window: 32,
        util_skew: 0.9,
        p99_ms: 50.0,
        cooldown_windows: 1,
        migration_factor: 0.0,
    });
    ccfg.fabric = Some(spec.clone());
    // Kill the chain's middle stage at ~35% of the ~640 ms run, recover
    // at ~55% — transfers are in flight on both sides of the cut.
    ccfg.faults = Some(FaultScript {
        events: vec![FaultEvent::BoardDown {
            board: plans[0].shards[1].board,
            at_ms: 224.0,
            recover_ms: Some(352.0),
        }],
    });
    let mut sink = TraceSink::enabled();
    let r = simulate_fleet_multi_tenant_traced(
        &cfg, &fleet, &specs, &weights, &plans, &ccfg, &mut sink,
    );
    assert_eq!(r.completed, 256, "the outage loses nothing");
    let f = r.faults.as_ref().expect("script armed");
    assert!(
        f.emergency_reshards >= 1,
        "severing the chain must force an emergency re-shard"
    );
    // The conservation law the carry fix protects: the fabric odometers
    // replay exactly from the events even though the plan's link channels
    // were rebuilt mid-run.
    let (per_seg, total) = replay_routes(&spec, 3, &sink.events).unwrap();
    segments_match(&r, &per_seg).unwrap();
    assert!(total > 0);
    assert_eq!(r.telemetry.as_ref().unwrap().route_bytes, Some(total));
}

/// `rack_down` is a correlated failure domain: both boards of the dead
/// rack fail together, and the topology-aware placement's cross-rack
/// replica spread is exactly what keeps the tenant serving.
#[test]
fn rack_down_fails_over_to_the_replica_in_the_other_rack() {
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone(), cfg.clone(), cfg.clone()];
    let spec = FabricSpec::leaf_spine(2);
    let specs = vec![TenantSpec {
        name: "svc".to_string(),
        network: tiny_vgg(),
        weights_seed: 1,
        arrival_rps: 400.0,
        requests: 256,
        load_steps: vec![],
        mode: ShardMode::Replicated,
        replicas: Some(2),
        slo: SloPolicy {
            p99_ms: 50.0,
            priority: 1,
            weight: 1.0,
            overload: None,
        },
    }];
    let weights: Vec<Weights> = specs
        .iter()
        .map(|s| Weights::random(&s.network, s.weights_seed))
        .collect();
    let fused = FusionPlan::fully_fused(7);
    let workloads: Vec<TenantWorkload> = specs
        .iter()
        .zip(&weights)
        .map(|(s, w)| TenantWorkload {
            name: &s.name,
            net: &s.network,
            weights: w,
            plan: &fused,
            mode: s.mode,
            priority: s.slo.priority,
            replicas: s.replicas,
        })
        .collect();
    let plans = place_tenants_capacity_fabric(
        &fleet,
        &workloads,
        &[0; 4],
        &[true; 4],
        &[1.0; 4],
        Some(&spec),
    )
    .expect("replicas place");
    let racks: BTreeSet<usize> = plans[0].shards.iter().map(|s| spec.rack_of(s.board)).collect();
    assert_eq!(racks.len(), 2, "replicas must land in different racks");

    let mut ccfg = pipelined_cfg(4, 1, 17);
    ccfg.mode = ShardMode::Replicated;
    ccfg.tenants = specs.clone();
    ccfg.preempt_mode = PreemptMode::Resume;
    ccfg.reshard = Some(ReshardPolicy {
        window: 32,
        util_skew: 0.9,
        p99_ms: 50.0,
        cooldown_windows: 1,
        migration_factor: 0.0,
    });
    ccfg.fabric = Some(spec.clone());
    ccfg.faults = Some(FaultScript {
        events: vec![FaultEvent::RackDown {
            rack: 0,
            at_ms: 224.0,
            recover_ms: Some(352.0),
        }],
    });
    ccfg.validate().expect("rack_down validates against the fabric");
    let mut sink = TraceSink::enabled();
    let r = simulate_fleet_multi_tenant_traced(
        &cfg, &fleet, &specs, &weights, &plans, &ccfg, &mut sink,
    );
    assert_eq!(r.completed, 256, "the surviving rack carries the tenant");
    let f = r.faults.as_ref().expect("script armed");
    assert_eq!(
        f.board_failures, 2,
        "rack_down fails every board of the rack together"
    );
    assert_eq!(f.board_recoveries, 2, "and recovery brings the rack back");
}
