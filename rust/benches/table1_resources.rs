//! E1 — Table I: resource utilization of the accelerator for the first two
//! conv layers + one pooling layer of VGG-16, paper vs structural model.
//! Also micro-benches the resource-model evaluation (the planner calls it
//! for every candidate plan).

use decoilfnet::accel::FusionPlan;
use decoilfnet::config::{vgg16_prefix, AccelConfig};
use decoilfnet::resources::{group_resources, plan_resources, utilization};
use decoilfnet::util::bench::Bencher;
use decoilfnet::util::table::Table;

/// Paper Table I (used / available).
const PAPER: &[(&str, usize, usize, f64)] = &[
    ("DSP", 605, 3600, 16.8),
    ("BRAM", 474, 1470, 32.24),
    ("LUT", 245_138, 433_200, 56.58),
    ("FF", 465_002, 866_400, 53.67),
];

fn main() {
    let cfg = AccelConfig::paper_default();
    let net = vgg16_prefix();
    let used = group_resources(&cfg, &net, 0..3); // conv1_1, conv1_2, pool1
    let u = utilization(used, &cfg);

    let measured = [
        ("DSP", used.dsp, cfg.platform.dsp, u.dsp_pct),
        ("BRAM", used.bram36(), cfg.platform.bram36, u.bram_pct),
        ("LUT", used.lut, cfg.platform.lut, u.lut_pct),
        ("FF", used.ff, cfg.platform.ff, u.ff_pct),
    ];

    let mut t = Table::new(&[
        "resource",
        "paper used",
        "model used",
        "available",
        "paper %",
        "model %",
    ])
    .title("Table I — resource utilization, first 2 conv + 1 pool of VGG-16")
    .label_col();
    for ((name, pu, pav, ppct), (mname, mu, mav, mpct)) in PAPER.iter().zip(&measured) {
        assert_eq!(name, mname);
        assert_eq!(*pav, *mav, "platform budget mismatch for {name}");
        t.row(&[
            name.to_string(),
            pu.to_string(),
            mu.to_string(),
            pav.to_string(),
            format!("{ppct:.1}%"),
            format!("{mpct:.1}%"),
        ]);
    }
    println!("{}", t.to_ascii());
    assert_eq!(used.dsp, 605, "DSP count is structural and must be exact");

    // Micro-bench: the planner evaluates this model 64× per search.
    let mut b = Bencher::new();
    b.bench("group_resources(conv1_1..pool1)", || {
        group_resources(&cfg, &net, 0..3)
    });
    b.bench("plan_resources(fully_fused_7)", || {
        plan_resources(&cfg, &net, &FusionPlan::fully_fused(7))
    });
}
