//! Compute-kernel bench: the depth-flattened im2col/MAC path vs the naive
//! per-pixel walk, plus fleet-simulator events/s (the event-queue inner
//! loops, static and dynamic).
//!
//! Layer shapes carry the paper nets' channel structure (VGG-16 prefix
//! depths/filters; the custom 4×conv64 net is the conv1_2 shape) at a
//! reduced 28×28 spatial extent — per-pixel work is what the kernel changes,
//! so speedups are extent-invariant while the naive side stays affordable
//! in CI. Wall-clock rates are machine-dependent and therefore **gate
//! exempt** in `BENCH_compute.json` (`"gate": false`); the deterministic
//! bit-exactness and simulator-determinism checks are the gated metrics.
//!
//! Set `BENCH_JSON=/path/out.json` to write the metrics file CI tracks, and
//! `DECOILFNET_THREADS` to pin the multi-threaded rows' worker count.

use std::time::Duration;

use decoilfnet::accel::depth_concat::FilterBanks;
use decoilfnet::accel::kernels::{self, conv2d_fx, naive, KernelScratch};
use decoilfnet::accel::{FusionPlan, Weights};
use decoilfnet::cluster::{simulate_fleet, simulate_fleet_dynamic, ShardPlan};
use decoilfnet::config::{
    tiny_vgg, vgg16_prefix, AccelConfig, ClusterConfig, Platform, PreemptMode, ShardMode,
};
use decoilfnet::tensor::NdTensor;
use decoilfnet::util::bench::{BenchConfig, Bencher};
use decoilfnet::util::json::Json;
use decoilfnet::util::stats::geomean;
use decoilfnet::util::table::Table;

/// Paper-net conv layer shapes: (name, input depth, filters).
const LAYERS: [(&str, usize, usize); 5] = [
    ("conv1_1", 3, 64),
    ("conv1_2", 64, 64),
    ("conv2_1", 64, 128),
    ("conv2_2", 128, 128),
    ("conv3_1", 128, 256),
];
const EXTENT: usize = 28;

fn bench_cfg() -> BenchConfig {
    BenchConfig {
        warmup: Duration::from_millis(60),
        measure: Duration::from_millis(700),
        min_samples: 2,
        max_samples: 8,
    }
}

struct LayerRow {
    name: &'static str,
    naive_px_s: f64,
    kernel_px_s: f64,
    kernel_mt_px_s: f64,
    speedup: f64,
}

fn main() {
    let mt_threads = kernels::default_threads();
    let mut b = Bencher::with_config(bench_cfg());
    let mut rows: Vec<LayerRow> = Vec::new();
    let mut bit_exact = true;

    for (i, &(name, d, k)) in LAYERS.iter().enumerate() {
        let seed = 100 + i as u64;
        let input = NdTensor::random(&[EXTENT, EXTENT, d], seed, -1.0, 1.0).to_fixed();
        let filt = NdTensor::random(&[k, 3, 3, d], seed ^ 1, -0.3, 0.3);
        let bias = NdTensor::random(&[k], seed ^ 2, -0.1, 0.1);
        let banks = FilterBanks::from_tensor(&filt, &bias);
        let out_px = (EXTENT * EXTENT) as f64;

        let mut scratch = KernelScratch::new();
        bit_exact &=
            conv2d_fx(&input, &banks, 1, true, 1, &mut scratch) ==
                naive::conv2d_fx_naive(&input, &banks, 1, true);

        let naive_ns = b
            .bench(&format!("naive/{name}"), || {
                naive::conv2d_fx_naive(&input, &banks, 1, true)
            })
            .ns_per_iter();
        let kernel_ns = b
            .bench(&format!("kernel/{name}"), || {
                conv2d_fx(&input, &banks, 1, true, 1, &mut scratch)
            })
            .ns_per_iter();
        let kernel_mt_ns = b
            .bench(&format!("kernel-mt{mt_threads}/{name}"), || {
                conv2d_fx(&input, &banks, 1, true, mt_threads, &mut scratch)
            })
            .ns_per_iter();

        rows.push(LayerRow {
            name,
            naive_px_s: out_px * 1e9 / naive_ns,
            kernel_px_s: out_px * 1e9 / kernel_ns,
            kernel_mt_px_s: out_px * 1e9 / kernel_mt_ns,
            speedup: naive_ns / kernel_ns,
        });
    }
    assert!(bit_exact, "kernel path must be bit-exact vs the naive oracle");

    let mut t = Table::new(&["layer", "naive px/s", "kernel px/s", "kernel-mt px/s", "speedup"])
        .title(&format!(
            "depth-flattened kernel vs naive walk ({EXTENT}×{EXTENT}, paper channel shapes, \
             single thread unless -mt)"
        ))
        .label_col();
    for r in &rows {
        t.row(&[
            r.name.to_string(),
            format!("{:.0}", r.naive_px_s),
            format!("{:.0}", r.kernel_px_s),
            format!("{:.0}", r.kernel_mt_px_s),
            format!("{:.2}×", r.speedup),
        ]);
    }
    println!("{}", t.to_ascii());
    let speedups: Vec<f64> = rows.iter().map(|r| r.speedup).collect();
    let geo = geomean(&speedups);
    println!("single-thread speedup geomean over paper layer shapes: {geo:.2}×");

    // ---- whole-network forward: frames/s on tiny-vgg ----
    let net = tiny_vgg();
    let w = Weights::random(&net, 1);
    let input = NdTensor::random(&net.input.as_slice(), 2, -1.0, 1.0).to_fixed();
    let mut scratch = KernelScratch::new();
    let fwd_ns = b
        .bench("forward/tiny-vgg/1t", || {
            kernels::forward_network_fx(&net, &w, &input, 1, &mut scratch)
        })
        .ns_per_iter();
    let fwd_mt_ns = b
        .bench(&format!("forward/tiny-vgg/{mt_threads}t"), || {
            kernels::forward_network_fx(&net, &w, &input, mt_threads, &mut scratch)
        })
        .ns_per_iter();
    let naive_fwd_ns = b
        .bench("forward/tiny-vgg/naive", || {
            naive::forward_network_fx_naive(&net, &w, &input)
        })
        .ns_per_iter();
    println!(
        "tiny-vgg forward: naive {:.1}/s, kernel {:.1}/s (1t), {:.1}/s ({mt_threads}t)",
        1e9 / naive_fwd_ns,
        1e9 / fwd_ns,
        1e9 / fwd_mt_ns
    );

    // ---- fleet simulator: events/s of the event-queue inner loops ----
    let vgg = vgg16_prefix();
    let vw = Weights::random(&vgg, 1);
    let cfg = AccelConfig::paper_default();
    let fused = FusionPlan::fully_fused(7);

    let static_shard = ShardPlan::replicated(&cfg, &vgg, &vw, &fused, 16);
    let static_ccfg = ClusterConfig {
        boards: 16,
        mode: ShardMode::Replicated,
        board_specs: vec![],
        link_bytes_per_cycle: f64::INFINITY,
        link_latency_cycles: 0,
        aggregate_ddr_bytes_per_cycle: None,
        arrival_rps: 50_000.0,
        load_steps: vec![],
        requests: 20_000,
        seed: 5,
        max_batch: 8,
        max_wait_us: 100.0,
        reshard: None,
        tenants: vec![],
        preempt_restart_cycles: 500,
        preempt_mode: PreemptMode::Restart,
        preempt_refill_cycles: 100,
        faults: None,
        fabric: None,
    };
    // Determinism is the gated invariant now that the legacy differential
    // oracle retired: re-running a simulator must reproduce the report
    // byte for byte (the committed fixtures under rust/tests/fixtures/
    // guard the values themselves).
    let r_event = simulate_fleet(&cfg, &static_shard, &static_ccfg);
    let mut sims_deterministic = r_event.to_json().to_string_pretty()
        == simulate_fleet(&cfg, &static_shard, &static_ccfg)
            .to_json()
            .to_string_pretty();

    let slow_gen = AccelConfig {
        platform: Platform::virtex7_older_gen(),
        ..cfg.clone()
    };
    let fleet: Vec<AccelConfig> = (0..16)
        .map(|i| if i % 2 == 0 { cfg.clone() } else { slow_gen.clone() })
        .collect();
    let dyn_shard = ShardPlan::replicated_fleet(&fleet, &vgg, &vw, &fused);
    let mut dyn_ccfg = static_ccfg.clone();
    dyn_ccfg.max_batch = 4;
    let rd_event = simulate_fleet_dynamic(&cfg, &fleet, &vgg, &vw, dyn_shard.clone(), &dyn_ccfg);
    sims_deterministic &= rd_event.to_json().to_string_pretty()
        == simulate_fleet_dynamic(&cfg, &fleet, &vgg, &vw, dyn_shard.clone(), &dyn_ccfg)
            .to_json()
            .to_string_pretty();
    assert!(sims_deterministic, "fleet simulators must be deterministic");

    let n_req = static_ccfg.requests as f64;
    let static_event_ns = b
        .bench("sim/static-16b/event-queue", || {
            simulate_fleet(&cfg, &static_shard, &static_ccfg)
        })
        .ns_per_iter();
    let dyn_event_ns = b
        .bench("sim/dynamic-16b/event-queue", || {
            simulate_fleet_dynamic(&cfg, &fleet, &vgg, &vw, dyn_shard.clone(), &dyn_ccfg)
        })
        .ns_per_iter();
    println!(
        "fleet sim events/s (16 boards, 20k arrivals): static {:.0}, dynamic {:.0}",
        n_req * 1e9 / static_event_ns,
        n_req * 1e9 / dyn_event_ns
    );

    // ---- BENCH_compute.json ----
    if let Ok(path) = std::env::var("BENCH_JSON") {
        let metric = |v: f64, better: &str, gate: bool| {
            Json::obj().set("value", v).set("better", better).set("gate", gate)
        };
        let mut m = Json::obj()
            .set("kernel_bit_exact", metric(1.0, "higher", true))
            .set("sim_deterministic", metric(1.0, "higher", true))
            .set("speedup_geomean", metric(geo, "higher", false))
            .set("forward_tiny_vgg_1t_items_per_s", metric(1e9 / fwd_ns, "higher", false))
            .set("forward_tiny_vgg_mt_items_per_s", metric(1e9 / fwd_mt_ns, "higher", false))
            .set(
                "sim_static_event_events_per_s",
                metric(n_req * 1e9 / static_event_ns, "higher", false),
            )
            .set(
                "sim_dynamic_event_events_per_s",
                metric(n_req * 1e9 / dyn_event_ns, "higher", false),
            );
        for r in &rows {
            m = m
                .set(&format!("naive_{}_items_per_s", r.name), metric(r.naive_px_s, "higher", false))
                .set(
                    &format!("kernel_{}_items_per_s", r.name),
                    metric(r.kernel_px_s, "higher", false),
                )
                .set(
                    &format!("kernel_mt_{}_items_per_s", r.name),
                    metric(r.kernel_mt_px_s, "higher", false),
                )
                .set(&format!("speedup_{}", r.name), metric(r.speedup, "higher", false));
        }
        let out = Json::obj()
            .set("schema", "decoilfnet-compute-bench/v1")
            .set("seeded", true)
            .set("metrics", m);
        std::fs::write(&path, out.to_string_pretty())
            .unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("wrote bench metrics to {path}");
    }
}
