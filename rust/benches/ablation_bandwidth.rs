//! Ablation A2 — DDR bandwidth sensitivity: the paper's §II claim that its
//! fused architecture is "optimized in a bandwidth constrained setup so
//! efficiently that the restricted external memory access is no longer the
//! bottleneck". Sweep channel bandwidth and show fused cycles stay flat
//! while unfused cycles blow up at low bandwidth.

use decoilfnet::accel::{Engine, FusionPlan, Weights};
use decoilfnet::config::{vgg16_prefix, AccelConfig};
use decoilfnet::util::stats::fmt_count;
use decoilfnet::util::table::Table;

fn main() {
    let net = vgg16_prefix();
    let weights = Weights::random(&net, 1);

    let mut t = Table::new(&[
        "DDR B/cycle",
        "fused kcycles",
        "fused slowdown",
        "unfused kcycles",
        "unfused slowdown",
    ])
    .title("A2 — bandwidth sensitivity, first 7 VGG-16 layers")
    .label_col();

    // Reference: ample bandwidth.
    let base = |plan: &FusionPlan, bw: f64| {
        let mut cfg = AccelConfig::paper_default();
        cfg.platform.ddr_bytes_per_cycle = bw;
        Engine::new(cfg).simulate(&net, &weights, plan).total_cycles
    };
    let fused = FusionPlan::fully_fused(7);
    let unfused = FusionPlan::unfused(7);
    let f_ref = base(&fused, 256.0);
    let u_ref = base(&unfused, 256.0);

    let mut rows = Vec::new();
    for bw in [256.0f64, 64.0, 16.0, 8.0, 4.0] {
        let f = base(&fused, bw);
        let u = base(&unfused, bw);
        t.row(&[
            format!("{bw:.0}"),
            fmt_count(f / 1000),
            format!("{:.2}X", f as f64 / f_ref as f64),
            fmt_count(u / 1000),
            format!("{:.2}X", u as f64 / u_ref as f64),
        ]);
        rows.push((bw, f as f64 / f_ref as f64, u as f64 / u_ref as f64));
    }
    println!("{}", t.to_ascii());

    // Shape assertions:
    // fused tolerates an 8 B/cycle channel with <20% slowdown …
    let f_at_8 = rows.iter().find(|r| r.0 == 8.0).unwrap().1;
    assert!(
        f_at_8 < 1.2,
        "fused slowdown at 8 B/cyc: {f_at_8:.2}X — fusion must hide bandwidth"
    );
    // … while unfused degrades much faster at every constrained point.
    for (bw, f, u) in &rows {
        if *bw <= 16.0 {
            assert!(
                u > f,
                "unfused must degrade faster at {bw} B/cyc: fused {f:.2}X unfused {u:.2}X"
            );
        }
    }
    let u_at_4 = rows.last().unwrap().2;
    let f_at_4 = rows.last().unwrap().1;
    println!(
        "at 4 B/cycle: fused {f_at_4:.2}X vs unfused {u_at_4:.2}X slowdown — \
         the paper's 'no longer the bottleneck' claim holds for the fused design"
    );
    assert!(u_at_4 / f_at_4 > 1.5);
}
