//! E3 — Table III: the paper's custom 4×conv-64 network — cumulative fused
//! timing must stay nearly flat while the CPU grows linearly.

use decoilfnet::accel::{Engine, FusionPlan, Weights};
use decoilfnet::baselines::cpu_ref::{forward_timed, CpuWeights};
use decoilfnet::config::{custom_4conv, AccelConfig, Network};
use decoilfnet::tensor::NdTensor;
use decoilfnet::util::table::{fmt_speedup, Table};

const PAPER: &[(&str, f64, f64, f64)] = &[
    ("conv_1", 114.54, 23.12, 26.764),
    ("conv_2", 736.78, 27.42, 27.01),
    ("conv_3", 1346.32, 35.45, 27.24),
    ("conv_4", 2113.24, 38.58, 27.48),
];

fn main() {
    let cfg = AccelConfig::paper_default();
    let full = custom_4conv();
    let engine = Engine::new(cfg.clone());

    eprintln!("measuring CPU baseline ...");
    let cpu_w = CpuWeights::random(&full, 1);
    let input = NdTensor::random(&full.input.as_slice(), 7, -1.0, 1.0);
    let (_, cpu_cum) = forward_timed(&full, &cpu_w, &input);

    let mut t = Table::new(&[
        "ending layer",
        "CPU meas ms",
        "sim ms",
        "speedup",
        "paper speedup",
    ])
    .title("Table III — four consecutive conv-64 layers")
    .label_col();

    let mut sims = Vec::new();
    for (i, layer) in full.layers.iter().enumerate() {
        let prefix = Network {
            name: format!("p{i}"),
            input: full.input,
            layers: full.layers[..=i].to_vec(),
        };
        let w = Weights::random(&prefix, 1);
        let rep = engine.simulate(&prefix, &w, &FusionPlan::fully_fused(i + 1));
        let sim_ms = rep.ms_at(cfg.platform.freq_mhz);
        let cpu_ms = cpu_cum[i].1;
        let (pname, pcpu, _pgpu, pours) = PAPER[i];
        assert_eq!(pname, layer.name());
        t.row(&[
            layer.name().to_string(),
            format!("{cpu_ms:.1}"),
            format!("{sim_ms:.2}"),
            fmt_speedup(cpu_ms / sim_ms),
            fmt_speedup(pcpu / pours),
        ]);
        sims.push((cpu_ms, sim_ms));
    }
    println!("{}", t.to_ascii());

    // Shape assertions (the paper's core claims for this network):
    // 1. fused pipeline is flat: conv_4 adds < 5% over conv_1;
    let flat = sims[3].1 / sims[0].1;
    assert!(flat < 1.05, "pipeline not flat: conv_4/conv_1 = {flat:.3}");
    println!("pipeline flatness conv_4/conv_1 = {flat:.4} (paper: 27.48/26.764 = 1.027)");
    // 2. speedup grows monotonically with fused depth;
    let speedups: Vec<f64> = sims.iter().map(|(c, s)| c / s).collect();
    for w in speedups.windows(2) {
        assert!(w[1] > w[0], "speedup must grow with fused depth: {speedups:?}");
    }
    println!("speedup growth: {:?}", speedups.iter().map(|s| format!("{s:.1}X")).collect::<Vec<_>>());
}
