//! E4 — Table IV: DeCoILFNet vs the Optimized [2] and Fused-layer [3]
//! accelerators on the first 7 VGG-16 layers: clock cycles, MB transferred
//! per input, BRAM and DSP.

use decoilfnet::accel::{Engine, FusionPlan, Weights};
use decoilfnet::baselines::{fused_layer, optimized};
use decoilfnet::config::{vgg16_prefix, AccelConfig};
use decoilfnet::resources::plan_resources;
use decoilfnet::util::bench::{e2e_config, Bencher};
use decoilfnet::util::table::Table;

fn main() {
    let cfg = AccelConfig::paper_default();
    let net = vgg16_prefix();
    let weights = Weights::random(&net, 1);

    // Ours.
    let engine = Engine::new(cfg.clone());
    let ours = engine.simulate(&net, &weights, &FusionPlan::fully_fused(7));
    let ours_res = plan_resources(&cfg, &net, &FusionPlan::fully_fused(7));

    // Baselines (both ran the same board at 100 MHz, 32-bit float).
    let ocfg = optimized::OptimizedConfig::zhang2015();
    let opt = optimized::run(&ocfg, &cfg, &net);
    let fus = fused_layer::run(&ocfg, &cfg, &net, 28);

    let mut t = Table::new(&["", "Optimized [2]", "Fused-layer [3]", "DeCoILFNet"])
        .title("Table IV — comparison with FPGA accelerators, first 7 VGG-16 layers")
        .label_col();
    t.row(&[
        "clock cycles ×10³ (model)".into(),
        (opt.total_cycles / 1000).to_string(),
        (fus.total_cycles / 1000).to_string(),
        (ours.total_cycles / 1000).to_string(),
    ]);
    t.row(&[
        "clock cycles ×10³ (paper)".into(),
        "10951".into(),
        "11655".into(),
        "5034".into(),
    ]);
    t.row(&[
        "precision".into(),
        "32 bits float".into(),
        "32 bits float".into(),
        "32 bits fixed".into(),
    ]);
    t.row(&["frequency MHz".into(), "100".into(), "100".into(), "120".into()]);
    t.row(&[
        "MB transferred (model)".into(),
        format!("{:.2}", opt.total_mb()),
        format!("{:.2}", fus.total_mb()),
        format!("{:.2}", ours.total_mb()),
    ]);
    t.row(&[
        "MB transferred (paper)".into(),
        "77.14".into(),
        "3.64".into(),
        "6.69".into(),
    ]);
    t.row(&[
        "BRAM (model, BRAM18)".into(),
        opt.bram18.to_string(),
        fus.bram18.to_string(),
        ours_res.bram18.to_string(),
    ]);
    t.row(&[
        "BRAM (paper)".into(),
        "2085".into(),
        "2509".into(),
        "2387".into(),
    ]);
    t.row(&[
        "DSP (model)".into(),
        opt.dsp.to_string(),
        fus.dsp.to_string(),
        ours_res.dsp.to_string(),
    ]);
    t.row(&[
        "DSP (paper)".into(),
        "2880".into(),
        "2987".into(),
        "2907".into(),
    ]);
    println!("{}", t.to_ascii());

    // Shape assertions — who wins and by roughly what factor:
    let cyc_vs_opt = opt.total_cycles as f64 / ours.total_cycles as f64;
    let cyc_vs_fus = fus.total_cycles as f64 / ours.total_cycles as f64;
    assert!(
        cyc_vs_opt > 2.0 && cyc_vs_opt < 5.0,
        "vs [2]: {cyc_vs_opt:.2}X (paper: 2.18X) — must stay >2X"
    );
    assert!(
        cyc_vs_fus > 2.0 && cyc_vs_fus < 5.0,
        "vs [3]: {cyc_vs_fus:.2}X (paper: 2.32X)"
    );
    let traffic_vs_opt = opt.total_mb() / ours.total_mb();
    assert!(
        traffic_vs_opt > 5.0,
        "traffic vs [2]: {traffic_vs_opt:.1}X (paper: 11.5X) — must be ≫1"
    );
    let traffic_vs_fus = fus.total_mb() / ours.total_mb();
    assert!(
        traffic_vs_fus < 1.5,
        "traffic vs [3]: {traffic_vs_fus:.2}X (paper: 0.54X — [3] moves less or similar)"
    );
    println!(
        "shape: >2X cycles vs both ([2]: {cyc_vs_opt:.2}X, [3]: {cyc_vs_fus:.2}X), \
         {traffic_vs_opt:.1}X less traffic than [2], ≈[3] on traffic"
    );

    // Micro-bench the three models (planner building blocks).
    let mut b = Bencher::with_config(e2e_config());
    b.bench("decoilfnet.simulate(vgg7)", || {
        engine.simulate(&net, &weights, &FusionPlan::fully_fused(7))
    });
    b.bench("zhang2015.run(vgg7)", || optimized::run(&ocfg, &cfg, &net));
    b.bench("fused_layer.run(vgg7)", || {
        fused_layer::run(&ocfg, &cfg, &net, 28)
    });
}
