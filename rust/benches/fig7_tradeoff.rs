//! E6 — Fig 7: the fusion-grouping design space — off-chip data volume vs
//! DSP usage over the named points A…G, plus the planner's full 64-plan
//! sweep and its Pareto frontier.

use decoilfnet::accel::fusion::fig7_points;
use decoilfnet::accel::latency::plan_traffic_bytes;
use decoilfnet::accel::Weights;
use decoilfnet::config::{vgg16_prefix, AccelConfig};
use decoilfnet::coordinator::cost_all_plans;
use decoilfnet::resources::plan_resources;
use decoilfnet::util::bench::Bencher;
use decoilfnet::util::table::Table;

fn main() {
    let cfg = AccelConfig::paper_default();
    let net = vgg16_prefix();
    let weights = Weights::random(&net, 1);

    // Named sweep A..G.
    let mut t = Table::new(&["point", "plan", "DDR MB", "intermediates MB", "DSP"])
        .title("Fig 7 — grouped fusion: off-chip volume vs DSP (A = none … G = all)")
        .label_col();
    let base_mb = {
        // Irreducible traffic: input + weights + final output (= point G).
        let g = fig7_points(&net).pop().unwrap().1;
        plan_traffic_bytes(&cfg, &net, &weights, &g) as f64 / (1024.0 * 1024.0)
    };
    let mut rows = Vec::new();
    for (label, plan) in fig7_points(&net) {
        let mb = plan_traffic_bytes(&cfg, &net, &weights, &plan) as f64 / (1024.0 * 1024.0);
        let dsp = plan_resources(&cfg, &net, &plan).dsp;
        t.row(&[
            label.to_string(),
            plan.label(),
            format!("{mb:.2}"),
            format!("{:.2}", mb - base_mb),
            dsp.to_string(),
        ]);
        rows.push((label, mb, dsp));
    }
    println!("{}", t.to_ascii());

    // Shape assertions — the paper's anchors:
    // A (no fusion) spills every intermediate; G spills none. The paper
    // quotes 23.54 MB for A, which is not derivable from its own layout —
    // conv1_1's output alone is 224·224·64·4B = 12.25 MB one-way, and the
    // six intermediate volumes sum to 41.3 MB one-way / 82.7 MB write+read
    // (our accounting). We assert our self-consistent number and record the
    // discrepancy in EXPERIMENTS.md E6.
    let a_inter = rows[0].1 - base_mb;
    assert!(
        (41.0..100.0).contains(&a_inter),
        "point A intermediates: {a_inter:.2} MB (write+read of 41.3 MB of volumes)"
    );
    let g_inter = rows[6].1 - base_mb;
    assert!(g_inter.abs() < 1e-6, "point G must move no intermediates");
    // Monotone trade-off along the curve.
    for w in rows.windows(2) {
        assert!(w[1].1 <= w[0].1, "traffic must fall A→G");
        assert!(w[1].2 >= w[0].2, "DSP must rise A→G");
    }
    println!(
        "anchors: A intermediates {:.2} MB (paper 23.54), G {:.2} MB; DSP {} → {}",
        a_inter, g_inter, rows[0].2, rows[6].2
    );

    // Full design space + Pareto frontier.
    let costs = cost_all_plans(&cfg, &net, &weights);
    let mut pareto: Vec<&decoilfnet::coordinator::PlanCost> = Vec::new();
    for c in costs.iter().filter(|c| c.fits) {
        let dominated = costs.iter().filter(|o| o.fits).any(|o| {
            (o.traffic_bytes < c.traffic_bytes && o.resources.dsp <= c.resources.dsp)
                || (o.traffic_bytes <= c.traffic_bytes && o.resources.dsp < c.resources.dsp)
        });
        if !dominated {
            pareto.push(c);
        }
    }
    println!(
        "design space: {} plans, {} feasible, {} on the traffic/DSP Pareto frontier",
        costs.len(),
        costs.iter().filter(|c| c.fits).count(),
        pareto.len()
    );

    // Micro-bench the planner sweep (it runs per serving-config change).
    let mut b = Bencher::new();
    b.bench("cost_all_plans(vgg7: 64 plans)", || {
        cost_all_plans(&cfg, &net, &weights).len()
    });
}
