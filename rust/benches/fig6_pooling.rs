//! E5 — Fig 6: speedup over the CPU with and without pooling layers, as a
//! function of fused depth. The paper's observation: fusing a pooling layer
//! costs extra fill latency (the pool buffer must fill before the next conv
//! sees a valid window), so the "with pooling" speedup curve sits below the
//! "without pooling" one.

use decoilfnet::accel::{Engine, FusionPlan, Weights};
use decoilfnet::baselines::cpu_ref::{forward_timed, CpuWeights};
use decoilfnet::config::{AccelConfig, Layer, Network, VolShape};
use decoilfnet::tensor::NdTensor;
use decoilfnet::util::table::{fmt_speedup, Table};

/// Build an n-layer net of conv-64s, optionally with a pool after every two
/// convs (the VGG pattern).
fn build(n_convs: usize, with_pool: bool) -> Network {
    let mut layers = Vec::new();
    for i in 0..n_convs {
        layers.push(Layer::conv3x3(&format!("conv_{}", i + 1), 64));
        if with_pool && i % 2 == 1 && i + 1 < n_convs {
            layers.push(Layer::pool2x2(&format!("pool_{}", i / 2 + 1)));
        }
    }
    Network {
        name: format!("fig6-{}conv{}", n_convs, if with_pool { "-pool" } else { "" }),
        input: VolShape::new(224, 224, 3),
        layers,
    }
}

fn main() {
    let cfg = AccelConfig::paper_default();
    let engine = Engine::new(cfg.clone());

    let mut t = Table::new(&[
        "convs",
        "no-pool sim ms",
        "no-pool speedup",
        "pool sim ms",
        "pool speedup",
    ])
    .title("Fig 6 — speedup vs CPU with and without pooling (X = #conv layers)")
    .label_col();

    let mut curves: Vec<(f64, f64)> = Vec::new();
    for n in [2usize, 4, 6] {
        let mut row = vec![n.to_string()];
        let mut pair = (0.0, 0.0);
        for (slot, with_pool) in [(0usize, false), (1, true)] {
            let net = build(n, with_pool);
            let w = Weights::random(&net, 1);
            let sim = engine.simulate(&net, &w, &FusionPlan::fully_fused(net.layers.len()));
            let sim_ms = sim.ms_at(cfg.platform.freq_mhz);

            let cpu_w = CpuWeights::random(&net, 1);
            let input = NdTensor::random(&net.input.as_slice(), 7, -1.0, 1.0);
            let (_, cum) = forward_timed(&net, &cpu_w, &input);
            let cpu_ms = cum.last().unwrap().1;
            let speedup = cpu_ms / sim_ms;
            row.push(format!("{sim_ms:.2}"));
            row.push(fmt_speedup(speedup));
            if slot == 0 {
                pair.0 = speedup;
            } else {
                pair.1 = speedup;
            }
        }
        t.row(&row);
        curves.push(pair);
    }
    println!("{}", t.to_ascii());

    // Shape assertions:
    // 1. both speedup curves grow with depth;
    for w in curves.windows(2) {
        assert!(w[1].0 > w[0].0, "no-pool curve must grow");
        assert!(w[1].1 > w[0].1, "pool curve must grow");
    }
    // 2. CPU cost of pooling is small but the fused pool adds latency, so
    //    the consecutive-conv (no-pool) configuration achieves at least as
    //    high a speedup per conv (the paper's Fig 6 gap).
    let last = curves.last().unwrap();
    println!(
        "at 6 convs: no-pool {:.1}X vs with-pool {:.1}X (paper's gap direction: no-pool ≥ pool)",
        last.0, last.1
    );
}
