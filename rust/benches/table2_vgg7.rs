//! E2 — Table II: cumulative time of the first seven VGG-16 layers —
//! DeCoILFNet (cycle-accurate sim at 120 MHz) vs the measured CPU software
//! baseline, against the paper's columns. Also micro-benches the simulator
//! itself (the L3 §Perf target: the full 7-layer sweep must be interactive).

use decoilfnet::accel::{Engine, FusionPlan, Weights};
use decoilfnet::baselines::cpu_ref::{forward_timed, CpuWeights};
use decoilfnet::config::{vgg16_prefix, AccelConfig, Network};
use decoilfnet::tensor::NdTensor;
use decoilfnet::util::bench::{e2e_config, Bencher};
use decoilfnet::util::table::{fmt_speedup, Table};

const PAPER: &[(&str, f64, f64, f64)] = &[
    ("conv1_1", 114.54, 23.12, 26.76),
    ("conv1_2", 736.78, 27.42, 27.01),
    ("pool1", 769.37, 27.15, 27.06),
    ("conv2_1", 1011.71, 29.31, 28.08),
    ("conv2_2", 1282.42, 33.45, 41.46),
    ("pool2", 1442.47, 33.57, 41.49),
    ("conv3_1", 1637.43, 34.81, 41.95),
];

fn main() {
    let cfg = AccelConfig::paper_default();
    let full = vgg16_prefix();
    let engine = Engine::new(cfg.clone());

    eprintln!("measuring CPU baseline (single forward pass) ...");
    let cpu_w = CpuWeights::random(&full, 1);
    let input = NdTensor::random(&full.input.as_slice(), 7, -1.0, 1.0);
    let (_, cpu_cum) = forward_timed(&full, &cpu_w, &input);

    let mut t = Table::new(&[
        "ending layer",
        "CPU meas ms",
        "sim ms",
        "speedup",
        "paper CPU ms",
        "paper ms",
        "paper speedup",
    ])
    .title("Table II — cumulative timing, first 7 layers of VGG-16")
    .label_col();

    let mut prev_sim = 0.0;
    for (i, layer) in full.layers.iter().enumerate() {
        let prefix = Network {
            name: format!("p{i}"),
            input: full.input,
            layers: full.layers[..=i].to_vec(),
        };
        let w = Weights::random(&prefix, 1);
        let rep = engine.simulate(&prefix, &w, &FusionPlan::fully_fused(i + 1));
        let sim_ms = rep.ms_at(cfg.platform.freq_mhz);
        let cpu_ms = cpu_cum[i].1;
        let (pname, pcpu, _pgpu, pours) = PAPER[i];
        assert_eq!(pname, layer.name());
        t.row(&[
            layer.name().to_string(),
            format!("{cpu_ms:.1}"),
            format!("{sim_ms:.2}"),
            fmt_speedup(cpu_ms / sim_ms),
            format!("{pcpu:.1}"),
            format!("{pours:.2}"),
            fmt_speedup(pcpu / pours),
        ]);
        // Shape assertions: cumulative times grow; fusion keeps growth far
        // below the CPU's linear growth. (A prefix ending in a pool may dip
        // by a few hundred cycles: its DDR output volume is 4× smaller than
        // the preceding conv prefix's, so the final write drains sooner.)
        assert!(sim_ms >= prev_sim - 0.05, "{sim_ms} << {prev_sim}");
        assert!(cpu_ms / sim_ms > 1.0, "accelerator must beat CPU");
        prev_sim = sim_ms;
    }
    println!("{}", t.to_ascii());

    // L3 perf micro-bench: one full 7-layer fused simulation.
    let w = Weights::random(&full, 1);
    let mut b = Bencher::with_config(e2e_config());
    b.bench("engine.simulate(vgg7, fused)", || {
        engine.simulate(&full, &w, &FusionPlan::fully_fused(7))
    });
    b.bench("engine.simulate(vgg7, unfused)", || {
        engine.simulate(&full, &w, &FusionPlan::unfused(7))
    });
}
