//! Ablation A1 — §V iterative decomposition and the fusion-vs-depth-
//! parallelism trade-off:
//!
//! 1. sweep the depth-parallelism cap d_par on the paper's 7-layer prefix —
//!    the optimum is d_par = 64 with full fusion; pushing to 128 costs BRAM,
//!    forces the planner to break the fusion ([7] → [5|2]) and slows down;
//! 2. on deep blocks (depths 128–256) raising d_par pays more than on the
//!    shallow prefix — the paper's "allocate compute to depth parallelism
//!    for later layers";
//! 3. feasibility scan of full VGG-16: the paper's fully-weight-resident
//!    architecture stops fitting the XC7V690T once conv4_x's 512-deep
//!    filter banks appear (9.4 MB of weights vs 6.46 MB of BRAM) — a §V
//!    limitation the paper concedes but never quantifies.

use decoilfnet::accel::{Engine, Weights};
use decoilfnet::config::{vgg16_full, vgg16_prefix, AccelConfig, Layer, Network, VolShape};
use decoilfnet::coordinator::{best_plan, Objective};
use decoilfnet::resources::plan_resources;
use decoilfnet::util::stats::fmt_count;
use decoilfnet::util::table::Table;

/// The conv3 block of VGG-16 as a standalone deep workload (input is pool2's
/// output): depths 128→256, where iterative decomposition is active.
fn conv3_block() -> Network {
    Network {
        name: "vgg16-conv3-block".into(),
        input: VolShape::new(56, 56, 128),
        layers: vec![
            Layer::conv3x3("conv3_1", 256),
            Layer::conv3x3("conv3_2", 256),
            Layer::conv3x3("conv3_3", 256),
            Layer::pool2x2("pool3"),
        ],
    }
}

fn sweep(net: &Network, label: &str) -> Vec<(usize, Option<u64>)> {
    let weights = Weights::random(net, 1);
    let mut t = Table::new(&[
        "d_par cap",
        "plan (latency winner)",
        "kcycles",
        "ms@120MHz",
        "DSP",
        "BRAM36",
    ])
    .title(&format!("A1 — depth-parallelism cap sweep, {label}"))
    .label_col();
    let mut out = Vec::new();
    for cap in [8usize, 16, 32, 64, 128] {
        let mut cfg = AccelConfig::paper_default();
        cfg.max_depth_parallel = cap;
        match best_plan(&cfg, net, &weights, Objective::Latency) {
            None => {
                t.row(&[
                    cap.to_string(),
                    "(infeasible)".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
                out.push((cap, None));
            }
            Some(pc) => {
                let rep = Engine::new(cfg.clone()).simulate(net, &weights, &pc.plan);
                let res = plan_resources(&cfg, net, &pc.plan);
                t.row(&[
                    cap.to_string(),
                    pc.plan.label(),
                    fmt_count(rep.total_cycles / 1000),
                    format!("{:.2}", rep.ms_at(120.0)),
                    res.dsp.to_string(),
                    res.bram36().to_string(),
                ]);
                out.push((cap, Some(rep.total_cycles)));
            }
        }
    }
    println!("{}", t.to_ascii());
    out
}

fn at(sweep: &[(usize, Option<u64>)], cap: usize) -> Option<u64> {
    sweep.iter().find(|s| s.0 == cap).and_then(|s| s.1)
}

fn main() {
    // 1. Prefix: U-shaped sweep — full fusion wins at 64, breaks at 128.
    let prefix = vgg16_prefix();
    let s_prefix = sweep(&prefix, "vgg16-prefix7");
    let p64 = at(&s_prefix, 64).expect("cap 64 feasible");
    let p128 = at(&s_prefix, 128).expect("cap 128 feasible (as a split plan)");
    let best = s_prefix.iter().filter_map(|s| s.1).min().unwrap();
    assert_eq!(best, p64, "prefix optimum must sit at cap 64 with full fusion");
    assert!(
        p128 > p64,
        "cap 128 must break the fusion and slow down ({p128} vs {p64})"
    );
    println!(
        "prefix: optimum d_par=64 fully fused; 128 forces a split (+{:.0}% cycles)\n",
        100.0 * (p128 as f64 / p64 as f64 - 1.0)
    );

    // 2. Deep block: depth parallelism pays more.
    let deep = conv3_block();
    let s_deep = sweep(&deep, "vgg16-conv3-block (depths 128→256)");
    // The S-V signal is at the top of the range: pushing d_par from 64 to
    // 128 still pays on the deep block (every layer has d >= 128) but
    // *hurts* the prefix (it must give up fusion to afford the width).
    let gain_64_128 =
        |s: &[(usize, Option<u64>)]| at(s, 64).unwrap() as f64 / at(s, 128).unwrap() as f64;
    let g_prefix = gain_64_128(&s_prefix);
    let g_deep = gain_64_128(&s_deep);
    println!("speedup from d_par 64->128: prefix {g_prefix:.2}X vs deep block {g_deep:.2}X");
    assert!(g_deep > 1.5, "deep block must keep gaining past 64 ({g_deep:.2}X)");
    assert!(g_prefix < 1.0, "prefix must regress past 64 ({g_prefix:.2}X)");

    // 3. Feasibility frontier of full VGG-16 under full weight residency.
    let full = vgg16_full();
    let cfg = AccelConfig::paper_default();
    let mut frontier = 0;
    for n in 1..=full.layers.len() {
        let sub = Network {
            name: format!("full[..{n}]"),
            input: full.input,
            layers: full.layers[..n].to_vec(),
        };
        let w = Weights::random(&sub, 1);
        if best_plan(&cfg, &sub, &w, Objective::Latency).is_some() {
            frontier = n;
        } else {
            break;
        }
    }
    println!(
        "\nfull VGG-16 feasibility frontier: first {frontier} layers (up to {}) fit the\n\
         XC7V690T with resident weights; beyond that conv4_x's 512-deep filter banks\n\
         (9.4 MB) exceed the 6.46 MB of BRAM — §V's 'weights dominate' limit, quantified.",
        full.layers[frontier.saturating_sub(1)].name()
    );
    assert!(
        (7..=13).contains(&frontier),
        "frontier {frontier} should fall inside the conv3/conv4 region"
    );
}
