//! Ablation A3 — serving-side batching policy: throughput and p95 latency of
//! the coordinator as max_batch varies, over the real PJRT artifacts.
//! (Skips gracefully if `make artifacts` has not been run.)

use std::path::PathBuf;
use std::time::{Duration, Instant};

use decoilfnet::coordinator::{BatchPolicy, Server, ServerConfig};
use decoilfnet::runtime::Runtime;
use decoilfnet::util::table::Table;

fn main() {
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("SKIP ablation_batching: run `make artifacts` first");
        return;
    }
    let rt = Runtime::load(&artifacts, "tiny-vgg").unwrap();
    let (input, _) = rt.golden().unwrap();

    let mut t = Table::new(&[
        "max_batch",
        "req/s",
        "mean batch",
        "p50 ms",
        "p95 ms",
    ])
    .title("A3 — batching policy sweep (tiny-vgg over PJRT, 64 req × 8 clients)")
    .label_col();

    let mut results = Vec::new();
    for max_batch in [1usize, 2, 4, 8, 16] {
        let srv = Server::start(ServerConfig {
            artifacts_dir: artifacts.clone(),
            network: "tiny-vgg".into(),
            default_plan: "fused".into(),
            batch: BatchPolicy {
                max_batch,
                max_wait: Duration::from_millis(2),
            },
        })
        .unwrap();
        let t0 = Instant::now();
        let mut joins = Vec::new();
        for _ in 0..8 {
            let h = srv.handle.clone();
            let input = input.clone();
            joins.push(std::thread::spawn(move || {
                for _ in 0..8 {
                    let resp = h.submit(input.clone(), None).wait().unwrap();
                    assert!(resp.result.is_ok());
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let m = srv.handle.metrics();
        let s = m.latency_summary().unwrap();
        let rps = 64.0 / wall;
        t.row(&[
            max_batch.to_string(),
            format!("{rps:.0}"),
            format!("{:.1}", m.mean_batch_size()),
            format!("{:.2}", s.median * 1e3),
            format!("{:.2}", s.p95 * 1e3),
        ]);
        results.push((max_batch, rps, m.mean_batch_size()));
        srv.shutdown();
    }
    println!("{}", t.to_ascii());

    // Shape: batching actually coalesces under concurrency.
    let b16 = results.iter().find(|r| r.0 == 16).unwrap();
    assert!(
        b16.2 > 1.5,
        "max_batch=16 should coalesce (mean {:.1})",
        b16.2
    );
    println!("batching coalesces under load (mean batch {:.1} at cap 16).", b16.2);
}
