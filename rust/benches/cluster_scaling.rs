//! Cluster scaling sweep — the fleet-level analogue of the paper's Fig 7
//! trade-off: 1→16 boards, replicated vs pipelined, fused vs unfused plans,
//! with and without the shared-DDR contention model. Emits a table plus a
//! machine-readable JSON array of {boards, mode, plan, contention,
//! throughput_rps, p99_ms, utilization[]} rows, and asserts the headline
//! shapes:
//!
//! * idealized (contention off) replicated throughput never decreases with
//!   boards (the pipelined analogue, which needs ideal links, is pinned in
//!   tests/integration_cluster.rs);
//! * contention never helps;
//! * the shared pool flattens the *unfused* fleet hard while the fused
//!   fleet keeps scaling — inter-layer fusion pays off again at fleet scale,
//!   because the bandwidth a board does not spend on intermediates is
//!   bandwidth its neighbors get to keep.

use decoilfnet::accel::{FusionPlan, Weights};
use decoilfnet::cluster::{simulate_fleet, ShardPlan};
use decoilfnet::config::{vgg16_prefix, AccelConfig, ClusterConfig, ShardMode};
use decoilfnet::coordinator::{best_plan, Objective};
use decoilfnet::util::json::Json;
use decoilfnet::util::table::Table;

struct Row {
    boards: usize,
    mode: ShardMode,
    plan: &'static str,
    contention: bool,
    throughput_rps: f64,
    p99_ms: f64,
    utilization: Vec<f64>,
}

fn sweep_cfg(boards: usize, mode: ShardMode, aggregate: Option<f64>) -> ClusterConfig {
    ClusterConfig {
        boards,
        mode,
        link_bytes_per_cycle: 16.0,
        link_latency_cycles: 64,
        aggregate_ddr_bytes_per_cycle: aggregate,
        arrival_rps: f64::INFINITY, // saturating burst → measures capacity
        requests: 192,
        seed: 1,
        max_batch: 8,
        max_wait_us: 200.0,
    }
}

fn main() {
    let cfg = AccelConfig::paper_default();
    let net = vgg16_prefix();
    let weights = Weights::random(&net, 1);
    // Shared pool worth two boards of off-chip bandwidth: from the third
    // co-located board on, DDR phases stretch.
    let pool = Some(2.0 * cfg.platform.ddr_bytes_per_cycle);

    let fused = best_plan(&cfg, &net, &weights, Objective::Latency)
        .expect("a plan fits the board")
        .plan;
    let plans: [(&'static str, FusionPlan); 2] =
        [("fused-best", fused), ("unfused", FusionPlan::unfused(7))];

    let mut rows = Vec::new();
    for (plan_name, plan) in plans.iter().map(|(n, p)| (*n, p)) {
        for mode in [ShardMode::Replicated, ShardMode::Pipelined] {
            for contention in [false, true] {
                for boards in 1..=16 {
                    let ccfg = sweep_cfg(boards, mode, if contention { pool } else { None });
                    let shard = match mode {
                        ShardMode::Replicated => {
                            ShardPlan::replicated(&cfg, &net, &weights, plan, boards)
                        }
                        ShardMode::Pipelined => {
                            ShardPlan::pipelined(&cfg, &net, &weights, plan, boards)
                        }
                    };
                    assert!(shard.fits(), "shard must fit the per-board budget");
                    let r = simulate_fleet(&cfg, &shard, &ccfg);
                    rows.push(Row {
                        boards,
                        mode,
                        plan: plan_name,
                        contention,
                        throughput_rps: r.throughput_rps,
                        p99_ms: r.p99_ms,
                        utilization: r.per_board.iter().map(|b| b.utilization).collect(),
                    });
                }
            }
        }
    }

    let find = |plan: &str, mode: ShardMode, boards: usize, cont: bool| {
        rows.iter()
            .find(|r| {
                r.plan == plan && r.mode == mode && r.boards == boards && r.contention == cont
            })
            .unwrap()
    };

    // Table: one line per (plan, mode, boards), idealized vs contended.
    let mut t = Table::new(&[
        "plan", "mode", "boards", "ideal req/s", "contended req/s", "ideal p99 ms",
        "contended p99 ms",
    ])
    .title("cluster scaling 1→16 boards (saturating load, pool = 2 boards of DDR)")
    .label_col();
    for (plan_name, _) in plans.iter().map(|(n, p)| (*n, p)) {
        for mode in [ShardMode::Replicated, ShardMode::Pipelined] {
            for boards in 1..=16 {
                let (ideal, cont) = (
                    find(plan_name, mode, boards, false),
                    find(plan_name, mode, boards, true),
                );
                t.row(&[
                    plan_name.to_string(),
                    mode.as_str().to_string(),
                    boards.to_string(),
                    format!("{:.1}", ideal.throughput_rps),
                    format!("{:.1}", cont.throughput_rps),
                    format!("{:.2}", ideal.p99_ms),
                    format!("{:.2}", cont.p99_ms),
                ]);
            }
        }
    }
    println!("{}", t.to_ascii());

    // Machine-readable dump.
    let mut arr = Json::Arr(vec![]);
    for r in &rows {
        let mut util = Json::Arr(vec![]);
        for &u in &r.utilization {
            util = util.push(u);
        }
        arr = arr.push(
            Json::obj()
                .set("boards", r.boards)
                .set("mode", r.mode.as_str())
                .set("plan", r.plan)
                .set("contention", r.contention)
                .set("throughput_rps", r.throughput_rps)
                .set("p99_ms", r.p99_ms)
                .set("utilization", util),
        );
    }
    println!("{}", arr.to_string_pretty());

    // Shape assertions.
    for (plan_name, _) in plans.iter().map(|(n, p)| (*n, p)) {
        // Idealized replicated throughput is monotone in board count.
        let ideal: Vec<f64> = (1..=16)
            .map(|b| find(plan_name, ShardMode::Replicated, b, false).throughput_rps)
            .collect();
        for w in ideal.windows(2) {
            assert!(
                w[1] >= w[0] * (1.0 - 1e-9),
                "{plan_name}: idealized replicated throughput fell {} → {}",
                w[0],
                w[1]
            );
        }
        // Contention never helps, in any mode.
        for mode in [ShardMode::Replicated, ShardMode::Pipelined] {
            for b in 1..=16usize {
                let (i, c) = (
                    find(plan_name, mode, b, false).throughput_rps,
                    find(plan_name, mode, b, true).throughput_rps,
                );
                assert!(c <= i * (1.0 + 1e-9), "{plan_name} {mode:?} {b}: contention helped?!");
            }
        }
    }
    // Flattening: on a 2-board pool at 16 replicated boards, the
    // traffic-heavy unfused fleet loses ≳40% of its idealized capacity;
    // the fused fleet, whose intermediates never leave the chip, keeps most
    // of its scaling. (Closed-form prediction: ratios ≈ 0.56 vs 0.83.)
    let ratio = |plan: &str| {
        find(plan, ShardMode::Replicated, 16, true).throughput_rps
            / find(plan, ShardMode::Replicated, 16, false).throughput_rps
    };
    let (r_fused, r_unfused) = (ratio("fused-best"), ratio("unfused"));
    assert!(
        r_unfused < 0.7,
        "unfused fleet should flatten on a shared pool: ratio {r_unfused:.3}"
    );
    assert!(
        r_fused > 0.75,
        "fused fleet should keep scaling: ratio {r_fused:.3}"
    );
    assert!(r_unfused < r_fused);
    println!(
        "scaling shapes verified: monotone ideal; contended/ideal at 16 boards: \
         fused {r_fused:.3} vs unfused {r_unfused:.3} — fusion defends fleet scaling"
    );
}
