//! Cluster scaling sweep — the fleet-level analogue of the paper's Fig 7
//! trade-off, in three acts:
//!
//! 1. **Homogeneous 1→16 boards**, replicated vs pipelined, fused vs
//!    unfused, with and without the shared-DDR contention model: the shared
//!    pool flattens the unfused fleet hard while the fused fleet keeps
//!    scaling — inter-layer fusion pays off again at fleet scale.
//! 2. **Heterogeneous two-generation fleets** (half current-gen 120 MHz,
//!    half older-gen 60 MHz with thinner DDR): delivered throughput is
//!    decided by the fleet mix and the planner's awareness of it, not by
//!    peak DSP count.
//! 3. **Load-step re-sharding**: a fleet starts on cuts balanced under a
//!    homogeneous assumption, traffic steps up 4×, and the re-shard
//!    controller migrates to a heterogeneity-aware plan — recovering the
//!    statically re-planned throughput to within a few percent.
//! 4. **Multi-tenant priorities**: two tenants share two boards; the
//!    low-priority tenant's burst grows across the sweep while the
//!    high-priority tenant's p99 must stay flat — preemption isolates the
//!    interactive tail from the bulk flood.
//! 5. **Unified control plane**: a replica-capped stream's load step blows
//!    its SLO, the tenant-aware re-shard controller scales it out, and the
//!    post-settle tail recovers to ≤1.1× its pre-step value — with
//!    work-preserving (`resume`) preemption billing fewer cycles than
//!    restart on the same trace (`mt_reshard_*` rows, gate-exempt).
//! 6. **Telemetry self-instrumentation**: act 5's load step re-run with
//!    the trace sink armed — `sim_events_per_sec` lands in
//!    `BENCH_cluster.json` as a gate-exempt trend row, while the
//!    deterministic heap-depth rows are armed against the committed
//!    baseline (coalesced heap depth is O(boards + tenants), and must
//!    stay that way).
//! 7. **Chaos recovery**: a scripted mid-run board outage on a 3-board
//!    fleet — in-flight work re-queued, tenants drained to the survivors,
//!    the board re-admitted on recovery; the post-recovery p99 ratio,
//!    re-queue volume, and recovery-time objective ship as gate-exempt
//!    `chaos_*` rows.
//! 8. **Graceful degradation**: a best-effort flood with an overload
//!    policy (shed → retry/backoff → abandon) through a mid-run
//!    compute-degrade brownout — the shed-aware goodput and the abandon
//!    rate ship as gate-exempt `shed_*` rows while the protected
//!    interactive tenant's SLO holds.
//! 9. **Interconnect fabric**: the same pipelined chain inside one rack
//!    vs split across two racks of a thin-uplink leaf-spine fabric —
//!    identical payload, different route; the makespan ratio ships as
//!    the gate-exempt `fabric_locality_speedup` row beside the hot
//!    uplink's `fabric_uplink_util`.
//!
//! Deterministic by construction (seeded arrivals, closed-form service
//! times), so the emitted metrics are bit-reproducible across machines —
//! except `sim_events_per_sec`, the one wall-clock row, which is exactly
//! why it ships gate-exempt. Set `BENCH_JSON=/path/out.json` to write
//! the `BENCH_cluster.json` trajectory point CI tracks against the
//! committed baseline at the repo root.

use decoilfnet::accel::latency::group_cost_estimate;
use decoilfnet::accel::{FusionPlan, Weights};
use decoilfnet::cluster::{
    balance_min_max, place_tenants, simulate_fleet, simulate_fleet_dynamic,
    simulate_fleet_multi_tenant, simulate_fleet_multi_tenant_traced, InterBoardLink, ShardPlan,
    TenantWorkload, TraceSink,
};
use decoilfnet::config::{
    tiny_vgg, vgg16_prefix, AccelConfig, ClusterConfig, FabricSpec, FaultEvent, FaultScript,
    LoadStep, OverloadPolicy, Platform, PreemptMode, ReshardPolicy, RetryPolicy, ShardMode,
    SloPolicy, TenantSpec,
};
use decoilfnet::coordinator::{best_plan, Objective};
use decoilfnet::util::json::Json;
use decoilfnet::util::table::Table;

struct Row {
    boards: usize,
    mode: ShardMode,
    plan: &'static str,
    contention: bool,
    throughput_rps: f64,
    p50_ms: f64,
    p99_ms: f64,
    utilization: Vec<f64>,
}

fn sweep_cfg(boards: usize, mode: ShardMode, aggregate: Option<f64>) -> ClusterConfig {
    ClusterConfig {
        boards,
        mode,
        board_specs: vec![],
        link_bytes_per_cycle: 16.0,
        link_latency_cycles: 64,
        aggregate_ddr_bytes_per_cycle: aggregate,
        arrival_rps: f64::INFINITY, // saturating burst → measures capacity
        load_steps: vec![],
        requests: 192,
        seed: 1,
        max_batch: 8,
        max_wait_us: 200.0,
        reshard: None,
        tenants: vec![],
        preempt_restart_cycles: 500,
        preempt_mode: PreemptMode::Restart,
        preempt_refill_cycles: 100,
        faults: None,
        fabric: None,
    }
}

/// The older board generation: half the clock, half the DDR draw.
fn slow_gen(base: &AccelConfig) -> AccelConfig {
    AccelConfig {
        platform: Platform::virtex7_older_gen(),
        ..base.clone()
    }
}

/// Half current-gen, half older-gen, alternating in rack order (fast at
/// even slots). Alternation matters for the pipelined planner, which maps
/// stage *i* to board *i*: a fast-boards-first order would let short
/// pipelines (≤ 7 stages here) run entirely on current-gen boards and the
/// "heterogeneous" rows would carry no heterogeneity signal at 16 boards.
fn two_gen_fleet(total: usize, base: &AccelConfig) -> Vec<AccelConfig> {
    let slow = slow_gen(base);
    (0..total)
        .map(|i| if i % 2 == 0 { base.clone() } else { slow.clone() })
        .collect()
}

fn main() {
    let cfg = AccelConfig::paper_default();
    let net = vgg16_prefix();
    let weights = Weights::random(&net, 1);
    // Shared pool worth two boards of off-chip bandwidth: from the third
    // co-located board on, DDR phases stretch.
    let pool = Some(2.0 * cfg.platform.ddr_bytes_per_cycle);

    let fused = best_plan(&cfg, &net, &weights, Objective::Latency)
        .expect("a plan fits the board")
        .plan;
    let plans: [(&'static str, FusionPlan); 2] =
        [("fused-best", fused), ("unfused", FusionPlan::unfused(7))];

    let mut rows = Vec::new();
    for (plan_name, plan) in plans.iter().map(|(n, p)| (*n, p)) {
        for mode in [ShardMode::Replicated, ShardMode::Pipelined] {
            for contention in [false, true] {
                for boards in 1..=16 {
                    let ccfg = sweep_cfg(boards, mode, if contention { pool } else { None });
                    let shard = match mode {
                        ShardMode::Replicated => {
                            ShardPlan::replicated(&cfg, &net, &weights, plan, boards)
                        }
                        ShardMode::Pipelined => {
                            ShardPlan::pipelined(&cfg, &net, &weights, plan, boards)
                        }
                    };
                    assert!(shard.fits(), "shard must fit the per-board budget");
                    let r = simulate_fleet(&cfg, &shard, &ccfg);
                    rows.push(Row {
                        boards,
                        mode,
                        plan: plan_name,
                        contention,
                        throughput_rps: r.throughput_rps,
                        p50_ms: r.p50_ms,
                        p99_ms: r.p99_ms,
                        utilization: r.per_board.iter().map(|b| b.utilization).collect(),
                    });
                }
            }
        }
    }

    let find = |plan: &str, mode: ShardMode, boards: usize, cont: bool| {
        rows.iter()
            .find(|r| {
                r.plan == plan && r.mode == mode && r.boards == boards && r.contention == cont
            })
            .unwrap()
    };

    // Table: one line per (plan, mode, boards), idealized vs contended.
    let mut t = Table::new(&[
        "plan", "mode", "boards", "ideal req/s", "contended req/s", "ideal p99 ms",
        "contended p99 ms",
    ])
    .title("cluster scaling 1→16 boards (saturating load, pool = 2 boards of DDR)")
    .label_col();
    for (plan_name, _) in plans.iter().map(|(n, p)| (*n, p)) {
        for mode in [ShardMode::Replicated, ShardMode::Pipelined] {
            for boards in 1..=16 {
                let (ideal, cont) = (
                    find(plan_name, mode, boards, false),
                    find(plan_name, mode, boards, true),
                );
                t.row(&[
                    plan_name.to_string(),
                    mode.as_str().to_string(),
                    boards.to_string(),
                    format!("{:.1}", ideal.throughput_rps),
                    format!("{:.1}", cont.throughput_rps),
                    format!("{:.2}", ideal.p99_ms),
                    format!("{:.2}", cont.p99_ms),
                ]);
            }
        }
    }
    println!("{}", t.to_ascii());

    // Machine-readable dump.
    let mut arr = Json::Arr(vec![]);
    for r in &rows {
        let mut util = Json::Arr(vec![]);
        for &u in &r.utilization {
            util = util.push(u);
        }
        arr = arr.push(
            Json::obj()
                .set("boards", r.boards)
                .set("mode", r.mode.as_str())
                .set("plan", r.plan)
                .set("contention", r.contention)
                .set("throughput_rps", r.throughput_rps)
                .set("p50_ms", r.p50_ms)
                .set("p99_ms", r.p99_ms)
                .set("utilization", util),
        );
    }
    println!("{}", arr.to_string_pretty());

    // Shape assertions.
    for (plan_name, _) in plans.iter().map(|(n, p)| (*n, p)) {
        // Idealized replicated throughput is monotone in board count.
        let ideal: Vec<f64> = (1..=16)
            .map(|b| find(plan_name, ShardMode::Replicated, b, false).throughput_rps)
            .collect();
        for w in ideal.windows(2) {
            assert!(
                w[1] >= w[0] * (1.0 - 1e-9),
                "{plan_name}: idealized replicated throughput fell {} → {}",
                w[0],
                w[1]
            );
        }
        // Contention never helps, in any mode.
        for mode in [ShardMode::Replicated, ShardMode::Pipelined] {
            for b in 1..=16usize {
                let (i, c) = (
                    find(plan_name, mode, b, false).throughput_rps,
                    find(plan_name, mode, b, true).throughput_rps,
                );
                assert!(c <= i * (1.0 + 1e-9), "{plan_name} {mode:?} {b}: contention helped?!");
            }
        }
    }
    // Flattening: on a 2-board pool at 16 replicated boards, the
    // traffic-heavy unfused fleet loses ≳40% of its idealized capacity;
    // the fused fleet, whose intermediates never leave the chip, keeps most
    // of its scaling. (Closed-form prediction: ratios ≈ 0.56 vs 0.83.)
    let ratio = |plan: &str| {
        find(plan, ShardMode::Replicated, 16, true).throughput_rps
            / find(plan, ShardMode::Replicated, 16, false).throughput_rps
    };
    let (r_fused, r_unfused) = (ratio("fused-best"), ratio("unfused"));
    assert!(
        r_unfused < 0.7,
        "unfused fleet should flatten on a shared pool: ratio {r_unfused:.3}"
    );
    assert!(
        r_fused > 0.75,
        "fused fleet should keep scaling: ratio {r_fused:.3}"
    );
    assert!(r_unfused < r_fused);
    println!(
        "scaling shapes verified: monotone ideal; contended/ideal at 16 boards: \
         fused {r_fused:.3} vs unfused {r_unfused:.3} — fusion defends fleet scaling"
    );

    // ------------------------------------------------------------------
    // Act 2: heterogeneous two-generation fleets (greedy dispatcher,
    // contention off to isolate the heterogeneity signal).
    // ------------------------------------------------------------------
    let unfused = FusionPlan::unfused(7);
    let mut hetero_rows: Vec<(usize, &str, &str, f64, f64)> = Vec::new();
    let mut ht = Table::new(&["boards", "fleet", "mode", "req/s", "p99 ms"])
        .title("heterogeneous fleets: half 120 MHz + half 60 MHz vs all 120 MHz (burst)")
        .label_col();
    for total in [2usize, 4, 8, 16] {
        for (fleet_name, fleet) in [
            ("2-gen", two_gen_fleet(total, &cfg)),
            ("all-fast", vec![cfg.clone(); total]),
        ] {
            for mode in [ShardMode::Replicated, ShardMode::Pipelined] {
                let shard = match mode {
                    ShardMode::Replicated => {
                        ShardPlan::replicated_fleet(&fleet, &net, &weights, &unfused)
                    }
                    ShardMode::Pipelined => {
                        ShardPlan::pipelined_fleet(&fleet, &net, &weights, &unfused)
                    }
                };
                assert!(shard.fits());
                let mut ccfg = sweep_cfg(total, mode, None);
                ccfg.max_batch = 4;
                let r = simulate_fleet_dynamic(&cfg, &fleet, &net, &weights, shard, &ccfg);
                ht.row(&[
                    total.to_string(),
                    fleet_name.to_string(),
                    mode.as_str().to_string(),
                    format!("{:.1}", r.throughput_rps),
                    format!("{:.2}", r.p99_ms),
                ]);
                hetero_rows.push((total, fleet_name, mode.as_str(), r.throughput_rps, r.p99_ms));
                if fleet_name == "2-gen" && mode == ShardMode::Replicated {
                    // Sanity: a mixed fleet cannot beat the same count of
                    // current-gen boards.
                    let all_fast =
                        ShardPlan::replicated(&cfg, &net, &weights, &unfused, total);
                    let rf = simulate_fleet_dynamic(
                        &cfg,
                        &vec![cfg.clone(); total],
                        &net,
                        &weights,
                        all_fast,
                        &ccfg,
                    );
                    assert!(
                        r.throughput_rps <= rf.throughput_rps * (1.0 + 1e-9),
                        "{total} boards: mixed fleet beat all-fast?!"
                    );
                }
            }
        }
    }
    println!("{}", ht.to_ascii());

    // ------------------------------------------------------------------
    // Act 3: load-step re-sharding on a 2-fast + 2-slow fleet.
    // ------------------------------------------------------------------
    let fleet = two_gen_fleet(4, &cfg);
    let totals: Vec<u64> = unfused
        .groups()
        .iter()
        .map(|g| group_cost_estimate(&cfg, &net, g.clone()).total())
        .collect();
    let naive_cuts = balance_min_max(&totals, fleet.len().min(totals.len()));
    let naive = ShardPlan::pipelined_fleet_with_cuts(&fleet, &net, &weights, &unfused, &naive_cuts);

    let mut ccfg = sweep_cfg(4, ShardMode::Pipelined, None);
    ccfg.requests = 512;
    ccfg.max_batch = 8;
    let link = InterBoardLink::new(ccfg.link_bytes_per_cycle, ccfg.link_latency_cycles);
    let ref_freq = cfg.platform.freq_mhz;
    let naive_cap = naive.capacity_rps(ccfg.max_batch, &link, ref_freq);
    let naive_item_ms: f64 = naive.shards.iter().map(|s| s.item_us()).sum::<f64>() / 1e3;
    ccfg.arrival_rps = 0.4 * naive_cap;
    ccfg.load_steps = vec![LoadStep {
        at_request: 128,
        rps: 1.3 * naive_cap,
    }];
    let policy = ReshardPolicy {
        window: 32,
        util_skew: 0.25,
        p99_ms: 3.0 * naive_item_ms,
        cooldown_windows: 2,
        migration_factor: 1.0,
    };

    // Statically re-planned baseline: the controller's own candidate
    // chooser, applied at t = 0, no re-sharding.
    let static_best = [
        ShardPlan::replicated_fleet(&fleet, &net, &weights, &unfused),
        ShardPlan::pipelined_fleet(&fleet, &net, &weights, &unfused),
    ]
    .into_iter()
    .filter(|p| p.fits())
    .max_by(|a, b| {
        a.capacity_rps(ccfg.max_batch, &link, ref_freq)
            .partial_cmp(&b.capacity_rps(ccfg.max_batch, &link, ref_freq))
            .unwrap()
    })
    .expect("some plan fits");
    let r_static =
        simulate_fleet_dynamic(&cfg, &fleet, &net, &weights, static_best.clone(), &ccfg);

    let mut dyn_cfg = ccfg.clone();
    dyn_cfg.reshard = Some(policy);
    let r_dyn = simulate_fleet_dynamic(&cfg, &fleet, &net, &weights, naive.clone(), &dyn_cfg);
    let r_frozen = simulate_fleet_dynamic(&cfg, &fleet, &net, &weights, naive, &ccfg);

    let recovery = r_dyn.throughput_rps / r_static.throughput_rps;
    println!(
        "load step (0.4→1.3× naive capacity at request 128, 2 fast + 2 slow boards):\n\
         naive frozen {:8.1} req/s p99 {:9.2} ms\n\
         controller   {:8.1} req/s p99 {:9.2} ms  ({} reshard(s))\n\
         static best  {:8.1} req/s p99 {:9.2} ms  [{}]\n\
         recovery: {:.3} of statically re-planned throughput",
        r_frozen.throughput_rps,
        r_frozen.p99_ms,
        r_dyn.throughput_rps,
        r_dyn.p99_ms,
        r_dyn.reshard_events.len(),
        r_static.throughput_rps,
        r_static.p99_ms,
        static_best.label(),
        recovery
    );

    // ------------------------------------------------------------------
    // Act 4: multi-tenant priorities — two tenants on two shared boards,
    // the bulk tenant's burst grows across the sweep.
    // ------------------------------------------------------------------
    let mt_fleet = vec![cfg.clone(), cfg.clone()];
    let tiny = tiny_vgg();
    let tiny_fused = FusionPlan::fully_fused(7);
    let mut mt_rows: Vec<(usize, f64, f64, u64)> = Vec::new();
    let mut mt = Table::new(&[
        "bulk burst", "hi p99 ms", "bulk p99 ms", "hi slo", "bulk preempted",
    ])
    .title("multi-tenant: interactive (prio 2, 1 ms SLO) vs growing bulk burst (prio 0)")
    .label_col();
    for bulk_requests in [32usize, 96, 160] {
        let specs = vec![
            TenantSpec {
                name: "interactive".to_string(),
                network: tiny.clone(),
                weights_seed: 1,
                arrival_rps: 1500.0,
                requests: 48,
                load_steps: vec![],
                mode: ShardMode::Replicated,
                replicas: None,
                slo: SloPolicy {
                    p99_ms: 1.0,
                    priority: 2,
                    weight: 1.0,
                    overload: None,
                },
            },
            TenantSpec {
                name: "bulk".to_string(),
                network: tiny.clone(),
                weights_seed: 2,
                arrival_rps: f64::INFINITY,
                requests: bulk_requests,
                load_steps: vec![],
                mode: ShardMode::Replicated,
                replicas: None,
                slo: SloPolicy {
                    p99_ms: 2.0,
                    priority: 0,
                    weight: 1.0,
                    overload: None,
                },
            },
        ];
        let tw: Vec<Weights> = specs
            .iter()
            .map(|s| Weights::random(&s.network, s.weights_seed))
            .collect();
        let workloads: Vec<TenantWorkload> = specs
            .iter()
            .zip(&tw)
            .map(|(s, w)| TenantWorkload {
                name: &s.name,
                net: &s.network,
                weights: w,
                plan: &tiny_fused,
                mode: s.mode,
                priority: s.slo.priority,
                replicas: s.replicas,
            })
            .collect();
        let plans = place_tenants(&mt_fleet, &workloads).expect("tenants place");
        let mut mt_cfg = sweep_cfg(2, ShardMode::Replicated, None);
        mt_cfg.max_batch = 8;
        mt_cfg.max_wait_us = 0.0;
        mt_cfg.seed = 7;
        let r = simulate_fleet_multi_tenant(&cfg, &mt_fleet, &specs, &tw, &plans, &mt_cfg);
        let hi = &r.tenants[0];
        let lo = &r.tenants[1];
        assert_eq!(hi.completed + lo.completed, r.completed, "conservation");
        assert_eq!(hi.preemptions, 0, "nobody outranks the interactive tenant");
        mt.row(&[
            bulk_requests.to_string(),
            format!("{:.3}", hi.p99_ms),
            format!("{:.3}", lo.p99_ms),
            if hi.slo_met { "MET" } else { "MISSED" }.to_string(),
            lo.preemptions.to_string(),
        ]);
        mt_rows.push((bulk_requests, hi.p99_ms, lo.p99_ms, lo.preemptions));
    }
    println!("{}", mt.to_ascii());
    // Shape: the bulk tail must grow with the flood while the interactive
    // tail stays isolated below it.
    assert!(
        mt_rows.windows(2).all(|w| w[1].0 > w[0].0 && w[1].2 >= w[0].2),
        "bulk p99 must be monotone in flood size"
    );
    for &(n, hi_p99, lo_p99, _) in &mt_rows {
        assert!(
            hi_p99 < lo_p99,
            "flood {n}: interactive tail {hi_p99} must stay below bulk {lo_p99}"
        );
    }

    // ------------------------------------------------------------------
    // Act 5: the unified control plane — tenant-aware re-sharding under a
    // load step, restart vs work-preserving preemption. A capped stream's
    // rate doubles past its single board's capacity; the controller uncaps
    // it onto both boards; the post-settle tail must recover to within
    // 1.1× the pre-step tail while Resume bills fewer cycles than Restart.
    // ------------------------------------------------------------------
    let mk_stream = |requests: usize, with_step: bool| TenantSpec {
        name: "stream".to_string(),
        network: tiny.clone(),
        weights_seed: 1,
        arrival_rps: 7500.0,
        requests,
        load_steps: if with_step {
            vec![LoadStep {
                at_request: 96,
                rps: 15000.0,
            }]
        } else {
            vec![]
        },
        mode: ShardMode::Replicated,
        replicas: Some(1),
        slo: SloPolicy {
            p99_ms: 0.5,
            priority: 2,
            weight: 1.0,
            overload: None,
        },
    };
    let mk_bulk = || TenantSpec {
        name: "bulk".to_string(),
        network: tiny.clone(),
        weights_seed: 2,
        arrival_rps: f64::INFINITY,
        requests: 64,
        load_steps: vec![],
        mode: ShardMode::Replicated,
        replicas: None,
        slo: SloPolicy {
            p99_ms: 5000.0,
            priority: 0,
            weight: 1.0,
            overload: None,
        },
    };
    let run_unified = |specs: &[TenantSpec], mode: PreemptMode, reshard: bool, trace: bool| {
        let tw: Vec<Weights> = specs
            .iter()
            .map(|s| Weights::random(&s.network, s.weights_seed))
            .collect();
        let workloads: Vec<TenantWorkload> = specs
            .iter()
            .zip(&tw)
            .map(|(s, w)| TenantWorkload {
                name: &s.name,
                net: &s.network,
                weights: w,
                plan: &tiny_fused,
                mode: s.mode,
                priority: s.slo.priority,
                replicas: s.replicas,
            })
            .collect();
        let plans = place_tenants(&mt_fleet, &workloads).expect("tenants place");
        let mut c = sweep_cfg(2, ShardMode::Replicated, None);
        c.max_batch = 8;
        c.max_wait_us = 0.0;
        c.seed = 11;
        c.link_bytes_per_cycle = 16.0;
        c.link_latency_cycles = 64;
        c.preempt_mode = mode;
        c.preempt_refill_cycles = 100;
        if reshard {
            c.reshard = Some(ReshardPolicy {
                window: 48,
                util_skew: 0.9,
                p99_ms: 50.0,
                cooldown_windows: 1,
                migration_factor: 1.0,
            });
        }
        let mut sink = if trace {
            TraceSink::enabled()
        } else {
            TraceSink::disabled()
        };
        let r =
            simulate_fleet_multi_tenant_traced(&cfg, &mt_fleet, specs, &tw, &plans, &c, &mut sink);
        (r, sink)
    };
    let billed = |r: &decoilfnet::cluster::FleetReport| {
        r.per_board.iter().map(|b| b.busy_cycles).sum::<u64>()
    };
    // Pre-step reference: same seed, stream truncated before the step.
    let ref_specs = vec![mk_stream(96, false), mk_bulk()];
    let (r_ref, _) = run_unified(&ref_specs, PreemptMode::Restart, true, false);
    assert!(r_ref.reshard_events.is_empty(), "reference must not trigger");
    let step_specs = vec![mk_stream(320, true), mk_bulk()];
    let (r_restart, _) = run_unified(&step_specs, PreemptMode::Restart, true, false);
    let (r_resume, _) = run_unified(&step_specs, PreemptMode::Resume, true, false);
    let (r_frozen, _) = run_unified(&step_specs, PreemptMode::Restart, false, false);
    assert!(
        !r_restart.reshard_events.is_empty() && !r_resume.reshard_events.is_empty(),
        "the load step must trigger a tenant-aware re-shard"
    );
    let tail = |r: &decoilfnet::cluster::FleetReport| {
        r.tenants[0].tail_p99_ms.expect("armed controller reports tails")
    };
    let recovery = tail(&r_restart) / r_ref.tenants[0].p99_ms;
    assert!(
        recovery <= 1.1,
        "post-reshard tail p99 must recover to <= 1.1x pre-step: {recovery:.3}"
    );
    let saved = billed(&r_restart).saturating_sub(billed(&r_resume));
    assert!(saved > 0, "resume must bill fewer cycles than restart");
    println!(
        "unified control plane (stream 7.5k→15k req/s at request 96, 1→2 replicas):\n\
         pre-step p99   {:8.4} ms\n\
         frozen p99     {:8.4} ms  (no controller — tail stays blown)\n\
         restart: {} reshard(s), tail p99 {:8.4} ms, billed {} cycles\n\
         resume:  {} reshard(s), tail p99 {:8.4} ms, billed {} cycles  (saved {})\n\
         recovery: {:.3} of the pre-step tail (gate: <= 1.1)",
        r_ref.tenants[0].p99_ms,
        r_frozen.tenants[0].p99_ms,
        r_restart.reshard_events.len(),
        tail(&r_restart),
        billed(&r_restart),
        r_resume.reshard_events.len(),
        tail(&r_resume),
        billed(&r_resume),
        saved,
        recovery,
    );

    // ------------------------------------------------------------------
    // Act 6: telemetry self-instrumentation — the same Resume run with
    // the trace sink armed, wall-clock timed. Tracing must not perturb
    // the simulation; event throughput is the one machine-dependent
    // number in this bench, so its row rides gate-exempt. The heap-depth
    // stats are deterministic and gate-armed: they pin the coalescing
    // invariant (depth ≤ id universe, not in-flight items).
    // ------------------------------------------------------------------
    let t0 = std::time::Instant::now();
    let (r_traced, tsink) = run_unified(&step_specs, PreemptMode::Resume, true, true);
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    assert_eq!(
        r_traced.makespan_cycles, r_resume.makespan_cycles,
        "tracing must not perturb the simulation"
    );
    let tel = tsink.summary().expect("armed sink yields a summary");
    let events_per_sec = tel.sim_events as f64 / wall_s;
    println!(
        "telemetry: {} trace events over {} sim events in {:.3} ms wall \
         ({:.0} sim events/s), heap depth max {} mean {:.2}",
        tel.events_total,
        tel.sim_events,
        wall_s * 1e3,
        events_per_sec,
        tel.heap_depth_max,
        tel.heap_depth_mean,
    );

    // ------------------------------------------------------------------
    // Act 7: chaos recovery — a scripted board outage mid-run on a
    // 3-board fleet. The control plane re-queues the dead board's
    // in-flight items under work-preserving preemption accounting,
    // drains both tenants to the survivors, and re-admits the board at
    // the next controller window after recovery. The headline numbers
    // (post-recovery p99 / pre-fault p99, and the re-queue volume) ride
    // gate-exempt as `chaos_*` rows.
    // ------------------------------------------------------------------
    let chaos_fleet = vec![cfg.clone(), cfg.clone(), cfg.clone()];
    let chaos_tenant = |name: &str, seed: u64| TenantSpec {
        name: name.to_string(),
        network: tiny.clone(),
        weights_seed: seed,
        arrival_rps: 400.0,
        requests: 256,
        load_steps: vec![],
        mode: ShardMode::Replicated,
        replicas: None,
        slo: SloPolicy {
            p99_ms: 5.0,
            priority: 1,
            weight: 1.0,
            overload: None,
        },
    };
    let chaos_specs = vec![chaos_tenant("alpha", 1), chaos_tenant("bravo", 2)];
    let chaos_w: Vec<Weights> = chaos_specs
        .iter()
        .map(|s| Weights::random(&s.network, s.weights_seed))
        .collect();
    let chaos_workloads: Vec<TenantWorkload> = chaos_specs
        .iter()
        .zip(&chaos_w)
        .map(|(s, w)| TenantWorkload {
            name: &s.name,
            net: &s.network,
            weights: w,
            plan: &tiny_fused,
            mode: s.mode,
            priority: s.slo.priority,
            replicas: s.replicas,
        })
        .collect();
    let chaos_plans = place_tenants(&chaos_fleet, &chaos_workloads).expect("tenants place");
    let mut chaos_ccfg = sweep_cfg(3, ShardMode::Replicated, None);
    chaos_ccfg.max_batch = 4;
    chaos_ccfg.max_wait_us = 0.0;
    chaos_ccfg.seed = 13;
    chaos_ccfg.preempt_mode = PreemptMode::Resume;
    chaos_ccfg.reshard = Some(ReshardPolicy {
        window: 32,
        util_skew: 0.9,
        p99_ms: 50.0,
        cooldown_windows: 1,
        migration_factor: 0.0,
    });
    chaos_ccfg.tenants = chaos_specs.clone();
    // ~640 ms span at 400 req/s per tenant: board 1 dies at 35% of the
    // run and comes back at 55%.
    chaos_ccfg.faults = Some(FaultScript {
        events: vec![FaultEvent::BoardDown {
            board: 1,
            at_ms: 224.0,
            recover_ms: Some(352.0),
        }],
    });
    let r_chaos = simulate_fleet_multi_tenant(
        &cfg,
        &chaos_fleet,
        &chaos_specs,
        &chaos_w,
        &chaos_plans,
        &chaos_ccfg,
    );
    assert_eq!(r_chaos.completed, 512, "the outage loses nothing");
    let f_chaos = r_chaos.faults.as_ref().expect("script armed");
    let chaos_ratio = match (f_chaos.pre_fault_p99_ms, f_chaos.recovery_p99_ms) {
        (Some(pre), Some(post)) => post / pre,
        _ => panic!("pre/post p99 populations must both be non-empty"),
    };
    println!(
        "chaos recovery (board 1 down 224→352 ms, 3 boards, 2 × 256 Poisson requests):\n\
         {} requeued item(s), {} emergency reshard(s), downtime {} cycles, \
         recovery p99 ratio {:.3}",
        f_chaos.items_requeued,
        f_chaos.emergency_reshards,
        f_chaos.downtime_cycles,
        chaos_ratio,
    );

    // ------------------------------------------------------------------
    // Act 8: graceful degradation — a best-effort burst with an overload
    // policy floods two boards while board 0 browns out to 30% capacity
    // mid-flood. Admission sheds the flood first (retry/backoff, then
    // abandon) and strict-priority preemption keeps the interactive
    // tenant's SLO intact; the shed-aware goodput and the abandon rate
    // ride gate-exempt as `shed_*` rows.
    // ------------------------------------------------------------------
    let shed_fleet = vec![cfg.clone(), cfg.clone()];
    let shed_specs = vec![
        TenantSpec {
            name: "interactive".to_string(),
            network: tiny.clone(),
            weights_seed: 1,
            arrival_rps: 2000.0,
            requests: 64,
            load_steps: vec![],
            mode: ShardMode::Replicated,
            replicas: None,
            slo: SloPolicy {
                p99_ms: 2.0,
                priority: 2,
                weight: 1.0,
                overload: None,
            },
        },
        TenantSpec {
            name: "best-effort".to_string(),
            network: tiny.clone(),
            weights_seed: 2,
            arrival_rps: f64::INFINITY,
            requests: 256,
            load_steps: vec![],
            mode: ShardMode::Replicated,
            replicas: None,
            slo: SloPolicy {
                p99_ms: 5000.0,
                priority: 0,
                weight: 1.0,
                overload: Some(OverloadPolicy {
                    deadline_ms: 2.0,
                    max_queue: 8,
                    retry: RetryPolicy {
                        max_attempts: 3,
                        backoff_base_ms: 0.2,
                        jitter: 0.5,
                    },
                }),
            },
        },
    ];
    let shed_w: Vec<Weights> = shed_specs
        .iter()
        .map(|s| Weights::random(&s.network, s.weights_seed))
        .collect();
    let shed_workloads: Vec<TenantWorkload> = shed_specs
        .iter()
        .zip(&shed_w)
        .map(|(s, w)| TenantWorkload {
            name: &s.name,
            net: &s.network,
            weights: w,
            plan: &tiny_fused,
            mode: s.mode,
            priority: s.slo.priority,
            replicas: s.replicas,
        })
        .collect();
    let shed_plans = place_tenants(&shed_fleet, &shed_workloads).expect("tenants place");
    let mut shed_ccfg = sweep_cfg(2, ShardMode::Replicated, None);
    shed_ccfg.max_batch = 8;
    shed_ccfg.max_wait_us = 0.0;
    shed_ccfg.seed = 7;
    shed_ccfg.tenants = shed_specs.clone();
    shed_ccfg.faults = Some(FaultScript {
        events: vec![FaultEvent::ComputeDegrade {
            board: 0,
            capacity_fraction: 0.3,
            at_ms: 0.5,
            recover_ms: Some(3.0),
        }],
    });
    let r_shed = simulate_fleet_multi_tenant(
        &cfg,
        &shed_fleet,
        &shed_specs,
        &shed_w,
        &shed_plans,
        &shed_ccfg,
    );
    let shed_hi = &r_shed.tenants[0];
    let shed_lo = &r_shed.tenants[1];
    assert_eq!(shed_hi.completed, 64, "the flood never touches the interactive tenant");
    assert!(
        shed_hi.slo_met,
        "interactive p99 {} must hold through flood + brownout",
        shed_hi.p99_ms
    );
    let shed_abandoned = shed_lo.abandoned.expect("policy armed") as f64;
    assert_eq!(
        shed_lo.completed as u64 + shed_abandoned as u64,
        256,
        "offered == completed + abandoned"
    );
    let shed_goodput = shed_lo.goodput_rps.expect("policy armed");
    let shed_abandon_rate = shed_abandoned / 256.0;
    println!(
        "graceful degradation (256-req flood, board 0 at 30% capacity 0.5→3.0 ms, 2 boards):\n\
         {} shed, {} retried, {} abandoned (rate {:.3}); best-effort goodput {:.1} req/s; \
         interactive p99 {:.3} ms (SLO {} ms, met)",
        shed_lo.shed.unwrap(),
        shed_lo.retried.unwrap(),
        shed_lo.abandoned.unwrap(),
        shed_abandon_rate,
        shed_goodput,
        shed_hi.p99_ms,
        shed_hi.slo_p99_ms,
    );

    // ------------------------------------------------------------------
    // Act 9: interconnect fabric — one pipelined chain placed inside a
    // rack vs split across two racks of a leaf-spine fabric with a thin
    // uplink. Cross-rack boundary volumes cross four segments instead of
    // one and serialize on both racks' uplinks, so locality is worth
    // real makespan; the speedup and the hot uplink's busy fraction
    // ship gate-exempt as `fabric_*` rows.
    // ------------------------------------------------------------------
    let fab_spec = FabricSpec {
        uplink_bytes_per_cycle: 1.0,
        ..FabricSpec::leaf_spine(2)
    };
    let fab_src = FusionPlan::unfused(7);
    let mut fab_local = ShardPlan::pipelined(&cfg, &net, &weights, &fab_src, 2);
    fab_local.boards = 4; // racks {0, 1} and {2, 3}
    let mut fab_cross = fab_local.clone();
    fab_cross.shards[1].board = 2; // second stage exiled to rack 1
    let mut fab_ccfg = sweep_cfg(4, ShardMode::Pipelined, None);
    fab_ccfg.requests = 96;
    fab_ccfg.fabric = Some(fab_spec);
    let r_fab_local = simulate_fleet(&cfg, &fab_local, &fab_ccfg);
    let r_fab_cross = simulate_fleet(&cfg, &fab_cross, &fab_ccfg);
    assert_eq!(
        r_fab_local.link_bytes_total, r_fab_cross.link_bytes_total,
        "placement moves the route, not the payload"
    );
    assert!(
        r_fab_cross.makespan_cycles > r_fab_local.makespan_cycles,
        "cross-rack boundaries must cost makespan ({} vs {})",
        r_fab_cross.makespan_cycles,
        r_fab_local.makespan_cycles
    );
    let fabric_locality_speedup =
        r_fab_cross.makespan_cycles as f64 / r_fab_local.makespan_cycles as f64;
    let fab_sum = r_fab_cross.fabric.as_ref().expect("fabric armed");
    let fabric_uplink_util = fab_sum
        .segments
        .iter()
        .filter(|s| s.kind == "uplink")
        .map(|s| s.utilization)
        .fold(0.0f64, f64::max);
    assert!(
        fabric_uplink_util > 0.0,
        "the uplinks carried the boundary traffic"
    );
    println!(
        "fabric locality (leaf-spine, 2 racks x 2 boards, uplink 1 B/cyc): in-rack makespan \
         {} cycles vs cross-rack {} ({:.3}x); hot uplink busy {:.0}%",
        r_fab_local.makespan_cycles,
        r_fab_cross.makespan_cycles,
        fabric_locality_speedup,
        100.0 * fabric_uplink_util,
    );

    // ------------------------------------------------------------------
    // BENCH_cluster.json: the tracked trajectory point. Every value here is
    // a deterministic model output (cycles → seconds at a fixed clock), so
    // a >10% move is a real model change, not noise.
    // ------------------------------------------------------------------
    if let Ok(path) = std::env::var("BENCH_JSON") {
        let metric = |v: f64, better: &str| {
            Json::obj().set("value", v).set("better", better)
        };
        let mut m = Json::obj();
        let tp1_ideal = find("fused-best", ShardMode::Replicated, 1, false).throughput_rps;
        let tp1_cont = find("fused-best", ShardMode::Replicated, 1, true).throughput_rps;
        for b in [1usize, 2, 4, 8, 16] {
            let ideal = find("fused-best", ShardMode::Replicated, b, false);
            let cont = find("fused-best", ShardMode::Replicated, b, true);
            m = m
                .set(
                    &format!("replicated_fused_ideal_rps_b{b}"),
                    metric(ideal.throughput_rps, "higher"),
                )
                .set(
                    &format!("replicated_fused_contended_rps_b{b}"),
                    metric(cont.throughput_rps, "higher"),
                )
                .set(
                    &format!("replicated_fused_contended_p50_ms_b{b}"),
                    metric(cont.p50_ms, "lower"),
                )
                .set(
                    &format!("replicated_fused_contended_p99_ms_b{b}"),
                    metric(cont.p99_ms, "lower"),
                )
                .set(
                    &format!("scaling_efficiency_ideal_b{b}"),
                    metric(ideal.throughput_rps / (b as f64 * tp1_ideal), "higher"),
                )
                .set(
                    &format!("scaling_efficiency_contended_b{b}"),
                    metric(cont.throughput_rps / (b as f64 * tp1_cont), "higher"),
                );
        }
        for (total, fleet_name, mode_name, tp, p99) in &hetero_rows {
            if *fleet_name == "2-gen" {
                m = m
                    .set(
                        &format!("hetero_2gen_b{total}_{mode_name}_rps"),
                        metric(*tp, "higher"),
                    )
                    .set(
                        &format!("hetero_2gen_b{total}_{mode_name}_p99_ms"),
                        metric(*p99, "lower"),
                    );
            }
        }
        m = m
            .set("load_step_recovery_ratio", metric(recovery, "higher"))
            .set("load_step_controller_rps", metric(r_dyn.throughput_rps, "higher"))
            .set("load_step_frozen_rps", metric(r_frozen.throughput_rps, "higher"));
        // Multi-tenant rows ride along gate-exempt until a CI artifact arms
        // them (new metrics are reported as untracked by the gate script).
        let exempt = |v: f64, better: &str| {
            Json::obj()
                .set("value", v)
                .set("better", better)
                .set("gate", false)
        };
        for (n, hi_p99, lo_p99, preempted) in &mt_rows {
            m = m
                .set(&format!("mt_hi_p99_ms_flood{n}"), exempt(*hi_p99, "lower"))
                .set(&format!("mt_lo_p99_ms_flood{n}"), exempt(*lo_p99, "lower"))
                .set(
                    &format!("mt_lo_preemptions_flood{n}"),
                    exempt(*preempted as f64, "lower"),
                );
        }
        // Unified control plane sweep — gate-exempt until extended from a
        // CI artifact (same arming path as the other mt_* rows).
        m = m
            .set("mt_reshard_recovery_ratio", exempt(recovery, "lower"))
            .set(
                "mt_reshard_events",
                exempt(r_restart.reshard_events.len() as f64, "lower"),
            )
            .set(
                "mt_reshard_tail_p99_ms_restart",
                exempt(tail(&r_restart), "lower"),
            )
            .set(
                "mt_reshard_tail_p99_ms_resume",
                exempt(tail(&r_resume), "lower"),
            )
            .set(
                "mt_reshard_billed_cycles_restart",
                exempt(billed(&r_restart) as f64, "lower"),
            )
            .set(
                "mt_reshard_billed_cycles_resume",
                exempt(billed(&r_resume) as f64, "lower"),
            )
            .set(
                "mt_reshard_resume_saved_cycles",
                exempt(saved as f64, "higher"),
            )
            .set(
                "mt_reshard_frozen_p99_ms",
                exempt(r_frozen.tenants[0].p99_ms, "lower"),
            );
        // Telemetry self-instrumentation (act 6): the events/s row is
        // wall-clock (machine-dependent) and stays a gate-exempt trend
        // signal. The heap-depth rows are deterministic and ARMED: with
        // same-instant flushes coalesced per event id, depth is bounded by
        // the id universe (boards + tenant cursors), so any regression back
        // toward per-item heap growth trips the gate against the committed
        // baseline.
        m = m
            .set("sim_events_per_sec", exempt(events_per_sec, "higher"))
            .set(
                "sim_heap_depth_max",
                metric(tel.heap_depth_max as f64, "lower"),
            )
            .set("sim_heap_depth_mean", metric(tel.heap_depth_mean, "lower"));
        // Chaos recovery headline rows (act 7) — gate-exempt like the
        // other fleet trend rows until a CI artifact arms them.
        m = m
            .set("chaos_recovery_p99_ratio", exempt(chaos_ratio, "lower"))
            .set(
                "chaos_items_requeued",
                exempt(f_chaos.items_requeued as f64, "lower"),
            )
            .set(
                "chaos_downtime_cycles",
                exempt(f_chaos.downtime_cycles as f64, "lower"),
            );
        // Recovery-time objective of the act 7 outage (fault onset → first
        // controller window back within 1.25× the pre-fault p99) plus the
        // act 8 graceful-degradation headline rows — gate-exempt on the
        // same arming path as the other fleet trend rows.
        m = m
            .set(
                "chaos_rto_ms",
                exempt(
                    f_chaos
                        .recovery_time_ms
                        .expect("armed controller stamps the RTO"),
                    "lower",
                ),
            )
            .set("shed_goodput_rps", exempt(shed_goodput, "higher"))
            .set("shed_abandon_rate", exempt(shed_abandon_rate, "lower"));
        // Fabric locality headline rows (act 9) — gate-exempt on the
        // same CI-artifact arming path as the other fleet trend rows.
        m = m
            .set(
                "fabric_locality_speedup",
                exempt(fabric_locality_speedup, "higher"),
            )
            .set("fabric_uplink_util", exempt(fabric_uplink_util, "lower"));
        let out = Json::obj()
            .set("schema", "decoilfnet-cluster-bench/v1")
            .set("seeded", true)
            .set("metrics", m);
        std::fs::write(&path, out.to_string_pretty())
            .unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("wrote bench metrics to {path}");
    }
}
