"""Unit tests for check_bench_regression.py (pure stdlib; the CI bench job
runs them before gating real metrics).

Covered behaviors, per the module docstring's contract:
  * direction handling — "higher" fails on drops, "lower" fails on rises,
    and improvements never fail;
  * "gate": false exemption — drift is reported but never fails the pair;
  * multi-pair mode — one bad pair fails the whole invocation;
  * missing tracked metric — fails; missing gate-exempt metric — does not;
  * seed mode — an unseeded or absent baseline schema-checks instead of
    gating; malformed current output fails.

Run: python3 -m unittest discover -s scripts
"""

import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import check_bench_regression as cbr


def doc(metrics, seeded=True, schema="decoilfnet-test-bench/v1"):
    return {"schema": schema, "seeded": seeded, "metrics": metrics}


def metric(value, better="higher", gate=None):
    m = {"value": value, "better": better}
    if gate is not None:
        m["gate"] = gate
    return m


class CheckPairBase(unittest.TestCase):
    def setUp(self):
        self.dir = tempfile.TemporaryDirectory()
        self.addCleanup(self.dir.cleanup)
        self._n = 0

    def write(self, payload):
        self._n += 1
        path = os.path.join(self.dir.name, f"doc{self._n}.json")
        with open(path, "w", encoding="utf-8") as f:
            json.dump(payload, f)
        return path

    def check(self, baseline, current, tol=0.10):
        return cbr.check_pair(self.write(baseline), self.write(current), tol, False)


class DirectionHandling(CheckPairBase):
    def test_higher_metric_fails_on_regression(self):
        base = doc({"rps": metric(100.0, "higher")})
        self.assertFalse(self.check(base, doc({"rps": metric(80.0, "higher")})))

    def test_higher_metric_passes_within_tolerance(self):
        base = doc({"rps": metric(100.0, "higher")})
        self.assertTrue(self.check(base, doc({"rps": metric(91.0, "higher")})))

    def test_higher_metric_improvement_passes(self):
        base = doc({"rps": metric(100.0, "higher")})
        self.assertTrue(self.check(base, doc({"rps": metric(150.0, "higher")})))

    def test_lower_metric_fails_on_rise(self):
        base = doc({"p99": metric(10.0, "lower")})
        self.assertFalse(self.check(base, doc({"p99": metric(12.0, "lower")})))

    def test_lower_metric_improvement_passes(self):
        base = doc({"p99": metric(10.0, "lower")})
        self.assertTrue(self.check(base, doc({"p99": metric(5.0, "lower")})))

    def test_zero_baseline_is_skipped(self):
        base = doc({"ratio": metric(0.0, "higher")})
        self.assertTrue(self.check(base, doc({"ratio": metric(-5.0, "higher")})))

    def test_tolerance_is_respected(self):
        base = doc({"rps": metric(100.0, "higher")})
        cur = doc({"rps": metric(75.0, "higher")})
        self.assertFalse(self.check(base, cur, tol=0.10))
        self.assertTrue(self.check(base, cur, tol=0.30))


class GateExemption(CheckPairBase):
    def test_exempt_drift_does_not_fail(self):
        base = doc({"wallclock": metric(100.0, "higher", gate=False)})
        self.assertTrue(self.check(base, doc({"wallclock": metric(10.0, "higher")})))

    def test_exempt_metric_may_disappear(self):
        base = doc({"wallclock": metric(100.0, "higher", gate=False)})
        self.assertTrue(self.check(base, doc({"other": metric(1.0)})))

    def test_gated_metric_disappearing_fails(self):
        base = doc({"rps": metric(100.0, "higher")})
        self.assertFalse(self.check(base, doc({"other": metric(1.0)})))

    def test_mixed_gated_and_exempt(self):
        base = doc(
            {
                "rps": metric(100.0, "higher"),
                "wallclock": metric(50.0, "higher", gate=False),
            }
        )
        cur = doc({"rps": metric(99.0, "higher"), "wallclock": metric(1.0, "higher")})
        self.assertTrue(self.check(base, cur))


class SeedMode(CheckPairBase):
    def test_unseeded_baseline_schema_checks_only(self):
        base = doc({"rps": metric(100.0)}, seeded=False)
        self.assertTrue(self.check(base, doc({"rps": metric(1.0)})))

    def test_absent_baseline_is_seed_mode(self):
        cur = self.write(doc({"rps": metric(1.0)}))
        missing = os.path.join(self.dir.name, "nope.json")
        self.assertTrue(cbr.check_pair(missing, cur, 0.10, False))

    def test_malformed_current_fails_even_in_seed_mode(self):
        cur = self.write({"schema": "bogus", "metrics": {}})
        missing = os.path.join(self.dir.name, "nope.json")
        self.assertFalse(cbr.check_pair(missing, cur, 0.10, False))

    def test_schema_mismatch_fails(self):
        base = doc({"rps": metric(100.0)}, schema="decoilfnet-aaa-bench/v1")
        cur = doc({"rps": metric(100.0)}, schema="decoilfnet-bbb-bench/v1")
        self.assertFalse(self.check(base, cur))

    def test_current_metric_without_direction_fails_schema(self):
        base = doc({"rps": metric(100.0)})
        cur = doc({"rps": {"value": 100.0, "better": "sideways"}})
        self.assertFalse(self.check(base, cur))


class UnifiedControlPlaneRows(CheckPairBase):
    """The mt_reshard_* rows the unified control plane emits (PR 5): they
    ride along gate-exempt until armed from a CI artifact, exactly like the
    earlier mt_* rows — and once armed, they gate like any other metric."""

    MT_RESHARD = {
        "mt_reshard_recovery_ratio": metric(0.88, "lower", gate=False),
        "mt_reshard_events": metric(1.0, "lower", gate=False),
        "mt_reshard_tail_p99_ms_restart": metric(0.368, "lower", gate=False),
        "mt_reshard_tail_p99_ms_resume": metric(0.368, "lower", gate=False),
        "mt_reshard_billed_cycles_restart": metric(4152892.0, "lower", gate=False),
        "mt_reshard_billed_cycles_resume": metric(3984042.0, "lower", gate=False),
        "mt_reshard_resume_saved_cycles": metric(168850.0, "higher", gate=False),
        "mt_reshard_frozen_p99_ms": metric(1.758, "lower", gate=False),
    }

    def test_new_rows_in_current_only_are_untracked_and_pass(self):
        # First CI run after the bench lands: baseline predates the rows.
        base = doc({"replicated_fused_ideal_rps_b1": metric(37.07)})
        cur_metrics = {"replicated_fused_ideal_rps_b1": metric(37.07)}
        cur_metrics.update(self.MT_RESHARD)
        self.assertTrue(self.check(base, doc(cur_metrics)))

    def test_exempt_reshard_rows_may_drift_without_failing(self):
        base = doc(dict(self.MT_RESHARD))
        drifted = {k: metric(m["value"] * 3.0, m["better"]) for k, m in self.MT_RESHARD.items()}
        self.assertTrue(self.check(base, doc(drifted)))

    def test_armed_reshard_rows_gate_regressions(self):
        # Once a maintainer arms the rows (drops "gate": false), a blown
        # recovery ratio fails the pair like any tracked metric.
        armed = {k: metric(m["value"], m["better"]) for k, m in self.MT_RESHARD.items()}
        base = doc(armed)
        bad = {k: dict(v) for k, v in armed.items()}
        bad["mt_reshard_recovery_ratio"] = metric(1.5, "lower")
        self.assertFalse(self.check(base, doc(bad)))
        good = {k: dict(v) for k, v in armed.items()}
        self.assertTrue(self.check(base, doc(good)))

    def test_armed_saved_cycles_gates_in_the_higher_direction(self):
        base = doc({"mt_reshard_resume_saved_cycles": metric(168850.0, "higher")})
        self.assertFalse(
            self.check(base, doc({"mt_reshard_resume_saved_cycles": metric(10.0, "higher")}))
        )
        self.assertTrue(
            self.check(
                base, doc({"mt_reshard_resume_saved_cycles": metric(200000.0, "higher")})
            )
        )


class TelemetryRows(CheckPairBase):
    """The telemetry self-instrumentation rows (PR 6): the bench times the
    armed trace sink and emits `sim_events_per_sec` plus heap-depth stats.
    They follow the same untracked -> exempt -> armed lifecycle as the
    mt_* rows; events/s is wall-clock (machine-dependent), so arming it
    only makes sense against a baseline produced on the same CI runner
    class — until then it is a trend row."""

    TELEMETRY = {
        "sim_events_per_sec": metric(2.4e6, "higher", gate=False),
        "sim_heap_depth_max": metric(14.0, "lower", gate=False),
        "sim_heap_depth_mean": metric(3.7, "lower", gate=False),
    }

    def test_new_rows_in_current_only_are_untracked_and_pass(self):
        # First CI run after the telemetry bench lands: the committed
        # baseline predates the rows, so they report as untracked.
        base = doc({"replicated_fused_ideal_rps_b1": metric(37.07)})
        cur_metrics = {"replicated_fused_ideal_rps_b1": metric(37.07)}
        cur_metrics.update(self.TELEMETRY)
        self.assertTrue(self.check(base, doc(cur_metrics)))

    def test_exempt_telemetry_rows_may_drift_without_failing(self):
        # A slow runner halving events/s (or a deeper heap) must never
        # fail the gate while the rows ride exempt.
        base = doc(dict(self.TELEMETRY))
        drifted = {
            "sim_events_per_sec": metric(1.1e6, "higher"),
            "sim_heap_depth_max": metric(40.0, "lower"),
            "sim_heap_depth_mean": metric(9.9, "lower"),
        }
        self.assertTrue(self.check(base, doc(drifted)))

    def test_exempt_telemetry_rows_may_disappear(self):
        # e.g. a bench invocation without the traced act.
        base = doc(dict(self.TELEMETRY))
        self.assertTrue(self.check(base, doc({"other": metric(1.0)})))

    def test_armed_events_per_sec_gates_throughput_regressions(self):
        # Once armed (pinned-runner baseline), a collapse in simulator
        # event throughput fails the pair like any tracked metric.
        base = doc({"sim_events_per_sec": metric(2.4e6, "higher")})
        self.assertFalse(
            self.check(base, doc({"sim_events_per_sec": metric(1.0e6, "higher")}))
        )
        self.assertTrue(
            self.check(base, doc({"sim_events_per_sec": metric(2.6e6, "higher")}))
        )

    def test_armed_heap_depth_gates_in_the_lower_direction(self):
        base = doc({"sim_heap_depth_max": metric(14.0, "lower")})
        self.assertFalse(self.check(base, doc({"sim_heap_depth_max": metric(28.0, "lower")})))
        self.assertTrue(self.check(base, doc({"sim_heap_depth_max": metric(12.0, "lower")})))


class FastPathRows(CheckPairBase):
    """The fast-path rows as armed by PR 10: `sim_heap_depth_max` and
    `sim_heap_depth_mean` carry the committed baseline (6.0 / 4.0 — head-
    room above the coalesced-queue id bound for the traced act's scene)
    with no "gate": false, so a regression back toward per-item heap
    growth fails the pair; `sim_events_per_sec` stays the one wall-clock
    exempt row and may drift or disappear freely."""

    ARMED = {
        "sim_events_per_sec": metric(2.0e6, "higher", gate=False),
        "sim_heap_depth_max": metric(6.0, "lower"),
        "sim_heap_depth_mean": metric(4.0, "lower"),
    }

    def test_coalesced_depths_within_baseline_pass(self):
        # The traced act's actual post-coalescing depths (≤ 4 ids) sit
        # under the armed headroom and pass as improvements.
        base = doc(dict(self.ARMED))
        cur = {
            "sim_events_per_sec": metric(1.2e6, "higher"),
            "sim_heap_depth_max": metric(4.0, "lower"),
            "sim_heap_depth_mean": metric(2.8, "lower"),
        }
        self.assertTrue(self.check(base, doc(cur)))

    def test_per_item_heap_growth_fails_the_armed_rows(self):
        # An uncoalesced queue on the same scene balloons with in-flight
        # items — depth in the tens — and must trip the gate.
        base = doc(dict(self.ARMED))
        cur = {
            "sim_events_per_sec": metric(2.0e6, "higher"),
            "sim_heap_depth_max": metric(14.0, "lower"),
            "sim_heap_depth_mean": metric(3.7, "lower"),
        }
        self.assertFalse(self.check(base, doc(cur)))

    def test_armed_depth_rows_may_not_disappear(self):
        # A bench invocation that drops the traced act loses a tracked
        # metric — hard failure, unlike the exempt events/s row.
        base = doc(dict(self.ARMED))
        cur = {"sim_events_per_sec": metric(2.0e6, "higher")}
        self.assertFalse(self.check(base, doc(cur)))

    def test_events_per_sec_stays_exempt(self):
        # A slow runner halving events/s never fails while the depth rows
        # hold; the row may also disappear entirely.
        base = doc(dict(self.ARMED))
        cur = {
            "sim_heap_depth_max": metric(6.0, "lower"),
            "sim_heap_depth_mean": metric(4.0, "lower"),
        }
        self.assertTrue(self.check(base, doc(cur)))
        cur["sim_events_per_sec"] = metric(0.9e6, "higher")
        self.assertTrue(self.check(base, doc(cur)))

    def test_mean_depth_tolerance_band(self):
        # One-sided 10% band on the armed mean: 4.4 is the edge, beyond
        # fails, under passes.
        base = doc({"sim_heap_depth_mean": metric(4.0, "lower")})
        self.assertTrue(self.check(base, doc({"sim_heap_depth_mean": metric(4.39, "lower")})))
        self.assertFalse(self.check(base, doc({"sim_heap_depth_mean": metric(4.5, "lower")})))


class ChaosRows(CheckPairBase):
    """The chaos-recovery rows (PR 7): the cluster bench's scripted board
    outage emits the post-recovery p99 ratio, the re-queue volume, and the
    billed downtime. Same untracked -> exempt -> armed lifecycle as the
    mt_* and telemetry rows; once armed, a blown recovery ratio (the fleet
    not returning to its pre-fault tail) gates like any tracked metric."""

    CHAOS = {
        "chaos_recovery_p99_ratio": metric(1.0, "lower", gate=False),
        "chaos_items_requeued": metric(2.0, "lower", gate=False),
        "chaos_downtime_cycles": metric(15360000.0, "lower", gate=False),
    }

    def test_new_rows_in_current_only_are_untracked_and_pass(self):
        # First CI run after the chaos act lands: the committed baseline
        # predates the rows, so they report as untracked.
        base = doc({"replicated_fused_ideal_rps_b1": metric(37.07)})
        cur_metrics = {"replicated_fused_ideal_rps_b1": metric(37.07)}
        cur_metrics.update(self.CHAOS)
        self.assertTrue(self.check(base, doc(cur_metrics)))

    def test_exempt_chaos_rows_may_drift_without_failing(self):
        # A fault-model change tripling the re-queue volume or stretching
        # recovery must never fail the gate while the rows ride exempt.
        base = doc(dict(self.CHAOS))
        drifted = {k: metric(m["value"] * 3.0, m["better"]) for k, m in self.CHAOS.items()}
        self.assertTrue(self.check(base, doc(drifted)))

    def test_exempt_chaos_rows_may_disappear(self):
        # e.g. a bench invocation without the chaos act.
        base = doc(dict(self.CHAOS))
        self.assertTrue(self.check(base, doc({"other": metric(1.0)})))

    def test_armed_recovery_ratio_gates_regressions(self):
        # Once armed, a fleet that no longer returns to its pre-fault
        # tail after recovery fails the pair like any tracked metric.
        base = doc({"chaos_recovery_p99_ratio": metric(1.0, "lower")})
        self.assertFalse(
            self.check(base, doc({"chaos_recovery_p99_ratio": metric(1.4, "lower")}))
        )
        self.assertTrue(
            self.check(base, doc({"chaos_recovery_p99_ratio": metric(1.0, "lower")}))
        )

    def test_armed_requeue_volume_gates_in_the_lower_direction(self):
        base = doc({"chaos_items_requeued": metric(2.0, "lower")})
        self.assertFalse(self.check(base, doc({"chaos_items_requeued": metric(6.0, "lower")})))
        self.assertTrue(self.check(base, doc({"chaos_items_requeued": metric(1.0, "lower")})))


class ShedRows(CheckPairBase):
    """The graceful-degradation rows (PR 8): the cluster bench's overload
    act floods a best-effort tenant through a brownout and emits the
    shed-aware goodput, the abandon rate, and the recovery-time objective
    of the chaos scene. Same untracked -> exempt -> armed lifecycle as the
    mt_*, telemetry, and chaos rows; once armed, collapsing goodput or a
    blown RTO gates like any tracked metric."""

    SHED = {
        "shed_goodput_rps": metric(5200.0, "higher", gate=False),
        "shed_abandon_rate": metric(0.18, "lower", gate=False),
        "chaos_rto_ms": metric(42.0, "lower", gate=False),
    }

    def test_new_rows_in_current_only_are_untracked_and_pass(self):
        # First CI run after the overload act lands: the committed baseline
        # predates the rows, so they report as untracked.
        base = doc({"replicated_fused_ideal_rps_b1": metric(37.07)})
        cur_metrics = {"replicated_fused_ideal_rps_b1": metric(37.07)}
        cur_metrics.update(self.SHED)
        self.assertTrue(self.check(base, doc(cur_metrics)))

    def test_exempt_shed_rows_may_drift_without_failing(self):
        # An admission-model change halving goodput or tripling the abandon
        # rate must never fail the gate while the rows ride exempt.
        base = doc(dict(self.SHED))
        drifted = {
            "shed_goodput_rps": metric(2100.0, "higher"),
            "shed_abandon_rate": metric(0.55, "lower"),
            "chaos_rto_ms": metric(130.0, "lower"),
        }
        self.assertTrue(self.check(base, doc(drifted)))

    def test_exempt_shed_rows_may_disappear(self):
        # e.g. a bench invocation without the overload act.
        base = doc(dict(self.SHED))
        self.assertTrue(self.check(base, doc({"other": metric(1.0)})))

    def test_armed_goodput_gates_in_the_higher_direction(self):
        # Once armed, a collapse in shed-aware goodput fails the pair.
        base = doc({"shed_goodput_rps": metric(5200.0, "higher")})
        self.assertFalse(
            self.check(base, doc({"shed_goodput_rps": metric(3000.0, "higher")}))
        )
        self.assertTrue(
            self.check(base, doc({"shed_goodput_rps": metric(5400.0, "higher")}))
        )

    def test_armed_rto_gates_in_the_lower_direction(self):
        # A fleet that takes materially longer to return within 1.25× of
        # its pre-fault p99 fails the armed pair.
        base = doc({"chaos_rto_ms": metric(42.0, "lower")})
        self.assertFalse(self.check(base, doc({"chaos_rto_ms": metric(90.0, "lower")})))
        self.assertTrue(self.check(base, doc({"chaos_rto_ms": metric(40.0, "lower")})))


class FabricRows(CheckPairBase):
    """The interconnect-fabric rows (PR 9): the cluster bench's fabric act
    runs the same pipelined chain in-rack and cross-rack over a thin-uplink
    leaf-spine and emits the locality speedup (cross / in-rack makespan)
    and the peak uplink utilization. Same untracked -> exempt -> armed
    lifecycle as the mt_*, telemetry, chaos, and shed rows; once armed, a
    collapsing locality speedup (the fabric no longer modeling cross-rack
    cost) or a hotter uplink gates like any tracked metric."""

    FABRIC = {
        "fabric_locality_speedup": metric(1.8, "higher", gate=False),
        "fabric_uplink_util": metric(0.62, "lower", gate=False),
    }

    def test_new_rows_in_current_only_are_untracked_and_pass(self):
        # First CI run after the fabric act lands: the committed baseline
        # predates the rows, so they report as untracked.
        base = doc({"replicated_fused_ideal_rps_b1": metric(37.07)})
        cur_metrics = {"replicated_fused_ideal_rps_b1": metric(37.07)}
        cur_metrics.update(self.FABRIC)
        self.assertTrue(self.check(base, doc(cur_metrics)))

    def test_exempt_fabric_rows_may_drift_without_failing(self):
        # A routing or topology-model change halving the locality gap or
        # saturating the uplink must never fail the gate while the rows
        # ride exempt.
        base = doc(dict(self.FABRIC))
        drifted = {
            "fabric_locality_speedup": metric(1.1, "higher"),
            "fabric_uplink_util": metric(0.97, "lower"),
        }
        self.assertTrue(self.check(base, doc(drifted)))

    def test_exempt_fabric_rows_may_disappear(self):
        # e.g. a bench invocation without the fabric act.
        base = doc(dict(self.FABRIC))
        self.assertTrue(self.check(base, doc({"other": metric(1.0)})))

    def test_armed_locality_speedup_gates_in_the_higher_direction(self):
        # Once armed, a fabric that stops charging for cross-rack hops
        # (speedup collapsing toward 1.0) fails the pair.
        base = doc({"fabric_locality_speedup": metric(1.8, "higher")})
        self.assertFalse(
            self.check(base, doc({"fabric_locality_speedup": metric(1.0, "higher")}))
        )
        self.assertTrue(
            self.check(base, doc({"fabric_locality_speedup": metric(2.1, "higher")}))
        )

    def test_armed_uplink_util_gates_in_the_lower_direction(self):
        base = doc({"fabric_uplink_util": metric(0.62, "lower")})
        self.assertFalse(self.check(base, doc({"fabric_uplink_util": metric(0.95, "lower")})))
        self.assertTrue(self.check(base, doc({"fabric_uplink_util": metric(0.55, "lower")})))


class MultiPairMain(CheckPairBase):
    def run_main(self, argv):
        old = sys.argv
        sys.argv = ["check_bench_regression.py"] + argv
        try:
            return cbr.main()
        finally:
            sys.argv = old

    def test_two_good_pairs_pass(self):
        b1 = self.write(doc({"a": metric(1.0)}))
        c1 = self.write(doc({"a": metric(1.0)}))
        b2 = self.write(doc({"b": metric(2.0, "lower")}))
        c2 = self.write(doc({"b": metric(2.0, "lower")}))
        self.assertEqual(self.run_main([b1, c1, b2, c2]), 0)

    def test_one_bad_pair_fails_the_invocation(self):
        b1 = self.write(doc({"a": metric(1.0)}))
        c1 = self.write(doc({"a": metric(1.0)}))
        b2 = self.write(doc({"b": metric(100.0, "higher")}))
        c2 = self.write(doc({"b": metric(1.0, "higher")}))
        self.assertEqual(self.run_main([b1, c1, b2, c2]), 1)
        # Order must not matter: bad pair first fails too.
        self.assertEqual(self.run_main([b2, c2, b1, c1]), 1)

    def test_odd_file_count_is_a_usage_error(self):
        b1 = self.write(doc({"a": metric(1.0)}))
        with self.assertRaises(SystemExit) as ctx:
            self.run_main([b1])
        self.assertEqual(ctx.exception.code, 2)

    def test_write_baseline_copies_current(self):
        base = doc({"a": metric(1.0)})
        cur = doc({"a": metric(1.05)})
        bpath, cpath = self.write(base), self.write(cur)
        self.assertTrue(cbr.check_pair(bpath, cpath, 0.10, True))
        with open(bpath, encoding="utf-8") as f:
            self.assertEqual(json.load(f)["metrics"]["a"]["value"], 1.05)


if __name__ == "__main__":
    unittest.main()
