#!/usr/bin/env python3
"""Gate CI on the benches' deterministic metrics.

Usage:
    check_bench_regression.py BASELINE.json CURRENT.json
                              [BASELINE2.json CURRENT2.json ...]
                              [--tolerance 0.10] [--write-baseline]

Each bench (`cargo bench --bench cluster_scaling`, `--bench compute_kernels`
with BENCH_JSON set) emits a flat map of tracked metrics, each
`{"value": <float>, "better": "higher" | "lower"[, "gate": false]}`.
Positional arguments are (baseline, current) file pairs — the bench job
gates the cluster and compute files in one invocation.

Comparison rules per metric present in the BASELINE:
  * better == "higher": fail when current < baseline * (1 - tolerance)
  * better == "lower":  fail when current > baseline * (1 + tolerance)
  * metric missing from CURRENT: fail (a tracked metric disappeared)
  * "gate": false in the BASELINE entry: report drift but never fail —
    wall-clock rates (items/s on the CI runner) are tracked for trend, not
    gated, while deterministic model outputs stay hard gates.

Metrics present only in CURRENT are listed as untracked — commit an
extended baseline to start gating them.

Seed mode: a baseline whose top level has `"seeded": false` (or an absent
baseline file) arms that pair's gate instead of enforcing it — the CURRENT
file is schema-checked and printed so a maintainer can commit it as the
repo-root baseline. Use `--write-baseline` to copy CURRENT over BASELINE
locally.
"""

import argparse
import json
import re
import shutil
import sys

SCHEMA_RE = re.compile(r"^decoilfnet-[a-z0-9_]+-bench/v1$")


def load(path):
    with open(path, "r", encoding="utf-8") as f:
        return json.load(f)


def check_schema(doc, path):
    errors = []
    if not SCHEMA_RE.match(str(doc.get("schema"))):
        errors.append(
            f"{path}: schema {doc.get('schema')!r} does not match "
            f"decoilfnet-<name>-bench/v1"
        )
    metrics = doc.get("metrics")
    if not isinstance(metrics, dict) or not metrics:
        errors.append(f"{path}: 'metrics' must be a non-empty object")
        return errors
    for name, m in metrics.items():
        if not isinstance(m, dict):
            errors.append(f"{path}: metric {name!r} is not an object")
            continue
        if not isinstance(m.get("value"), (int, float)):
            errors.append(f"{path}: metric {name!r} has no numeric 'value'")
        if m.get("better") not in ("higher", "lower"):
            errors.append(f"{path}: metric {name!r} 'better' must be higher|lower")
        if not isinstance(m.get("gate", True), bool):
            errors.append(f"{path}: metric {name!r} 'gate' must be a bool")
    return errors


def check_pair(baseline_path, current_path, tol, write_baseline):
    """Gate one (baseline, current) pair; returns True when it passes."""
    current = load(current_path)
    errors = check_schema(current, current_path)
    if errors:
        print(f"current bench output {current_path} is malformed:")
        for e in errors:
            print(f"  - {e}")
        return False

    try:
        baseline = load(baseline_path)
    except FileNotFoundError:
        baseline = None

    if baseline is None or not baseline.get("seeded", False):
        print(
            f"[{baseline_path}] baseline is absent or unseeded — seed mode: "
            "schema-checking the fresh metrics instead of gating."
        )
        print(
            f"to arm the gate, commit the generated file as {baseline_path} "
            "(deterministic metrics are identical on every machine):"
        )
        print(json.dumps(current, indent=2, sort_keys=True))
        if write_baseline:
            shutil.copyfile(current_path, baseline_path)
            print(f"wrote {baseline_path}")
        return True

    if baseline.get("schema") != current.get("schema"):
        print(
            f"FAIL: {baseline_path} schema {baseline.get('schema')!r} != "
            f"{current_path} schema {current.get('schema')!r}"
        )
        return False

    base_metrics = baseline["metrics"]
    cur_metrics = current["metrics"]
    regressions, improvements, exempt_drift, missing = [], [], [], []

    for name, base in sorted(base_metrics.items()):
        gated = base.get("gate", True)
        if name not in cur_metrics:
            if gated:
                missing.append(name)
            else:
                print(f"note: gate-exempt metric absent from current: {name}")
            continue
        bv, cv = base["value"], cur_metrics[name]["value"]
        better = base["better"]
        if bv == 0:
            continue  # nothing to compare against
        delta = (cv - bv) / abs(bv)
        worse = cv < bv * (1.0 - tol) if better == "higher" else cv > bv * (1.0 + tol)
        better_now = cv > bv * (1.0 + tol) if better == "higher" else cv < bv * (1.0 - tol)
        if worse:
            (regressions if gated else exempt_drift).append((name, bv, cv, delta))
        elif better_now:
            improvements.append((name, bv, cv, delta))

    new = sorted(set(cur_metrics) - set(base_metrics))
    if new:
        print(f"note: {len(new)} new untracked metric(s): {', '.join(new)}")
    for name, bv, cv, delta in improvements:
        print(f"improved: {name}: {bv:.6g} -> {cv:.6g} ({delta:+.1%})")
    for name, bv, cv, delta in exempt_drift:
        print(f"drift (gate-exempt): {name}: {bv:.6g} -> {cv:.6g} ({delta:+.1%})")

    ok = True
    if missing:
        ok = False
        for name in missing:
            print(f"FAIL: tracked metric disappeared: {name}")
    if regressions:
        ok = False
        for name, bv, cv, delta in regressions:
            print(
                f"FAIL: {name} regressed beyond {tol:.0%}: "
                f"{bv:.6g} -> {cv:.6g} ({delta:+.1%})"
            )
    if ok:
        n = len(base_metrics)
        print(f"[{baseline_path}] all {n} tracked metrics within {tol:.0%} of baseline")
        if write_baseline:
            shutil.copyfile(current_path, baseline_path)
            print(f"wrote {baseline_path}")
    return ok


def main():
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument(
        "files",
        nargs="+",
        metavar="BASELINE CURRENT",
        help="one or more (baseline, current) JSON file pairs",
    )
    ap.add_argument("--tolerance", type=float, default=0.10)
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="copy each CURRENT over its BASELINE after a successful run",
    )
    args = ap.parse_args()
    if len(args.files) % 2 != 0:
        ap.error("files must come in (baseline, current) pairs")

    ok = True
    for i in range(0, len(args.files), 2):
        ok &= check_pair(args.files[i], args.files[i + 1], args.tolerance, args.write_baseline)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
