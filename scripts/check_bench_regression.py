#!/usr/bin/env python3
"""Gate CI on the cluster bench's deterministic metrics.

Usage:
    check_bench_regression.py BASELINE.json CURRENT.json [--tolerance 0.10]
                              [--write-baseline]

The bench (`cargo bench --bench cluster_scaling` with BENCH_JSON set) emits
a flat map of tracked metrics, each `{"value": <float>, "better": "higher" |
"lower"}`. Every value is a deterministic simulation output — cycles at a
fixed clock, no wall time — so any move beyond the tolerance is a real model
change, not machine noise.

Comparison rules per metric present in the BASELINE:
  * better == "higher": fail when current < baseline * (1 - tolerance)
  * better == "lower":  fail when current > baseline * (1 + tolerance)
  * metric missing from CURRENT: fail (a tracked metric disappeared)

Seed mode: a baseline whose top level has `"seeded": false` (or an absent
baseline file) arms the gate instead of enforcing it — the CURRENT file is
schema-checked and printed so a maintainer can commit it as the repo-root
`BENCH_cluster.json`, turning the gate on for every later run. Use
`--write-baseline` to copy CURRENT over BASELINE locally.
"""

import argparse
import json
import shutil
import sys

SCHEMA = "decoilfnet-cluster-bench/v1"


def load(path):
    with open(path, "r", encoding="utf-8") as f:
        return json.load(f)


def check_schema(doc, path):
    errors = []
    if doc.get("schema") != SCHEMA:
        errors.append(f"{path}: schema {doc.get('schema')!r} != {SCHEMA!r}")
    metrics = doc.get("metrics")
    if not isinstance(metrics, dict) or not metrics:
        errors.append(f"{path}: 'metrics' must be a non-empty object")
        return errors
    for name, m in metrics.items():
        if not isinstance(m, dict):
            errors.append(f"{path}: metric {name!r} is not an object")
            continue
        if not isinstance(m.get("value"), (int, float)):
            errors.append(f"{path}: metric {name!r} has no numeric 'value'")
        if m.get("better") not in ("higher", "lower"):
            errors.append(f"{path}: metric {name!r} 'better' must be higher|lower")
    return errors


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--tolerance", type=float, default=0.10)
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="copy CURRENT over BASELINE after a successful run",
    )
    args = ap.parse_args()

    current = load(args.current)
    errors = check_schema(current, args.current)
    if errors:
        print("current bench output is malformed:")
        for e in errors:
            print(f"  - {e}")
        return 1

    try:
        baseline = load(args.baseline)
    except FileNotFoundError:
        baseline = None

    if baseline is None or not baseline.get("seeded", False):
        print(
            "baseline is absent or unseeded — seed mode: schema-checking the "
            "fresh metrics instead of gating."
        )
        print(
            f"to arm the gate, commit the generated file as {args.baseline} "
            "(it is deterministic — identical on every machine):"
        )
        print(json.dumps(current, indent=2, sort_keys=True))
        if args.write_baseline:
            shutil.copyfile(args.current, args.baseline)
            print(f"wrote {args.baseline}")
        return 0

    base_metrics = baseline["metrics"]
    cur_metrics = current["metrics"]
    tol = args.tolerance
    regressions, improvements, missing = [], [], []

    for name, base in sorted(base_metrics.items()):
        if name not in cur_metrics:
            missing.append(name)
            continue
        bv, cv = base["value"], cur_metrics[name]["value"]
        better = base["better"]
        if bv == 0:
            continue  # nothing to compare against
        delta = (cv - bv) / abs(bv)
        if better == "higher":
            if cv < bv * (1.0 - tol):
                regressions.append((name, bv, cv, delta))
            elif cv > bv * (1.0 + tol):
                improvements.append((name, bv, cv, delta))
        else:
            if cv > bv * (1.0 + tol):
                regressions.append((name, bv, cv, delta))
            elif cv < bv * (1.0 - tol):
                improvements.append((name, bv, cv, delta))

    new = sorted(set(cur_metrics) - set(base_metrics))
    if new:
        print(f"note: {len(new)} new untracked metric(s): {', '.join(new)}")
    for name, bv, cv, delta in improvements:
        print(f"improved: {name}: {bv:.6g} -> {cv:.6g} ({delta:+.1%})")

    ok = True
    if missing:
        ok = False
        for name in missing:
            print(f"FAIL: tracked metric disappeared: {name}")
    if regressions:
        ok = False
        for name, bv, cv, delta in regressions:
            print(
                f"FAIL: {name} regressed beyond {tol:.0%}: "
                f"{bv:.6g} -> {cv:.6g} ({delta:+.1%})"
            )
    if ok:
        n = len(base_metrics)
        print(f"all {n} tracked metrics within {tol:.0%} of baseline")
        if args.write_baseline:
            shutil.copyfile(args.current, args.baseline)
            print(f"wrote {args.baseline}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
