"""AOT compilation: lower each fusion group to an HLO-text artifact the rust
runtime loads via PJRT. Build-time only — python never runs at serve time.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the image's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids.
(See /opt/xla-example/README.md.)

Outputs under --out (default ../artifacts):

  manifest.json            network spec + plans + file index
  <net>/g<i>_<lo>_<hi>.hlo.txt   one HLO module per fusion group
  <net>/weights/w<i>_filter.bin  raw little-endian f32 [k,kh,kw,c]
  <net>/weights/w<i>_bias.bin    raw little-endian f32 [k]
  <net>/golden_input.bin   a deterministic sample input
  <net>/golden_output.bin  reference forward of that input

Usage: python -m compile.aot [--out DIR] [--nets tiny-vgg,paper-example]
"""

import argparse
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


WEIGHT_SEED = 20180101  # fixed: artifacts are reproducible bit-for-bit


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (return_tuple so the rust side
    unwraps with to_tuple1).

    print_large_constants is ESSENTIAL: the default printer elides big weight
    constants as `{...}`, which the xla_extension 0.5.1 text parser silently
    reads back as zeros — the executable then computes all-zero outputs.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # Newer metadata attributes (source_end_line etc.) are unknown to the
    # 0.5.1 text parser; metadata is debug-only, drop it.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def lower_group(net, params, lo, hi, use_pallas=True):
    """Jit + lower layers [lo, hi) as a single-input HLO module (weights are
    baked as constants — the artifact is self-contained)."""
    shapes = model.layer_shapes(net)
    in_shape = shapes[lo]

    def fn(x):
        return (model.group_forward(x, net, params, lo, hi,
                                    use_pallas=use_pallas),)

    spec = jax.ShapeDtypeStruct(in_shape, jnp.float32)
    return jax.jit(fn).lower(spec)


def default_plans(net):
    """Plans to compile: fully fused + unfused (+ the paper's mid splits for
    7-layer nets, used by the Fig 7 serving demo)."""
    n = len(net["layers"])
    plans = {"fused": [n], "unfused": [1] * n}
    if n == 7:
        plans["split232"] = [2, 3, 2]
    return plans


def sample_input(net, seed=7):
    rng = np.random.default_rng(seed)
    h, w, d = net["input"]["h"], net["input"]["w"], net["input"]["d"]
    return rng.uniform(-1.0, 1.0, size=(h, w, d)).astype(np.float32)


def build_net(net_name, out_dir, use_pallas=True):
    net = model.NETWORKS[net_name]()
    params = model.init_params(net, WEIGHT_SEED)
    shapes = model.layer_shapes(net)
    net_dir = os.path.join(out_dir, net_name)
    wdir = os.path.join(net_dir, "weights")
    os.makedirs(wdir, exist_ok=True)

    entry = {
        "network": net,
        "shapes": [list(s) for s in shapes],
        "weight_seed": WEIGHT_SEED,
        "weights": [],
        "plans": {},
    }

    for i, p in enumerate(params):
        if p is None:
            continue
        filt, bias = p
        fpath = f"weights/w{i}_filter.bin"
        bpath = f"weights/w{i}_bias.bin"
        filt.tofile(os.path.join(net_dir, fpath))
        bias.tofile(os.path.join(net_dir, bpath))
        entry["weights"].append(
            {
                "layer": i,
                "name": net["layers"][i]["name"],
                "filter": fpath,
                "filter_shape": list(filt.shape),
                "bias": bpath,
                "bias_shape": list(bias.shape),
            }
        )

    for plan_name, sizes in default_plans(net).items():
        groups = []
        for gi, (lo, hi) in enumerate(model.plan_groups(net, sizes)):
            hlo_rel = f"g{gi}_{lo}_{hi}.hlo.txt"
            text = to_hlo_text(lower_group(net, params, lo, hi, use_pallas))
            with open(os.path.join(net_dir, hlo_rel), "w") as f:
                f.write(text)
            groups.append(
                {
                    "index": gi,
                    "lo": lo,
                    "hi": hi,
                    "hlo": hlo_rel,
                    "in_shape": list(shapes[lo]),
                    "out_shape": list(shapes[hi]),
                }
            )
            print(f"  {net_name}/{plan_name} group {gi} [{lo},{hi}) "
                  f"-> {hlo_rel} ({len(text)} chars)")
        entry["plans"][plan_name] = {"group_sizes": sizes, "groups": groups}

    # Golden vectors for runtime verification without python.
    x = sample_input(net)
    y = np.asarray(model.reference_forward(jnp.asarray(x), net, params))
    x.tofile(os.path.join(net_dir, "golden_input.bin"))
    y.astype(np.float32).tofile(os.path.join(net_dir, "golden_output.bin"))
    entry["golden"] = {
        "input": "golden_input.bin",
        "input_shape": list(x.shape),
        "output": "golden_output.bin",
        "output_shape": list(y.shape),
    }
    return entry


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--nets",
        default="tiny-vgg,paper-example",
        help="comma-separated network names (VGG-224 nets are compile-heavy "
        "under interpret mode; the timing experiments use the rust "
        "simulator and do not need their HLO)",
    )
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower through the pure-jnp reference instead of "
                    "the Pallas kernels (debugging aid)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest = {"version": 1, "networks": {}}
    for net_name in args.nets.split(","):
        net_name = net_name.strip()
        print(f"building {net_name} ...")
        manifest["networks"][net_name] = build_net(
            net_name, args.out, use_pallas=not args.no_pallas
        )
    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
