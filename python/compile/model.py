"""Layer-2: the JAX model — VGG-like networks composed from the L1 Pallas
kernels, grouped per fusion plan.

The network specification mirrors the rust `config::Network` JSON exactly, so
one description drives both sides. Each fusion group becomes one jitted
function (weights closed over as constants) that `aot.py` lowers to an HLO
artifact; within a group, consecutive conv pairs lower through the fused
Pallas kernel (intermediates never leave the chip), matching what the rust
engine simulates.
"""

import numpy as np
import jax.numpy as jnp

from .kernels.conv3x3 import conv3x3
from .kernels.fused_block import fused_conv2
from .kernels.pool import maxpool
from .kernels import ref


# ----------------------------------------------------------------------
# Network specs (mirror rust config::network builders)
# ----------------------------------------------------------------------

def conv(name, filters, kernel=3, stride=1, padding=1, relu=True):
    return {
        "type": "conv",
        "name": name,
        "kernel": kernel,
        "filters": filters,
        "stride": stride,
        "padding": padding,
        "relu": relu,
    }


def pool(name, window=2, stride=2):
    return {"type": "maxpool", "name": name, "window": window, "stride": stride}


def vgg16_prefix():
    return {
        "name": "vgg16-prefix7",
        "input": {"h": 224, "w": 224, "d": 3},
        "layers": [
            conv("conv1_1", 64),
            conv("conv1_2", 64),
            pool("pool1"),
            conv("conv2_1", 128),
            conv("conv2_2", 128),
            pool("pool2"),
            conv("conv3_1", 256),
        ],
    }


def custom_4conv():
    return {
        "name": "custom-4conv64",
        "input": {"h": 224, "w": 224, "d": 3},
        "layers": [conv(f"conv_{i}", 64) for i in range(1, 5)],
    }


def paper_test_example():
    return {
        "name": "paper-example",
        "input": {"h": 5, "w": 5, "d": 3},
        "layers": [conv("conv_a", 3), conv("conv_b", 3), pool("pool")],
    }


def tiny_vgg():
    return {
        "name": "tiny-vgg",
        "input": {"h": 32, "w": 32, "d": 3},
        "layers": [
            conv("conv1_1", 8),
            conv("conv1_2", 8),
            pool("pool1"),
            conv("conv2_1", 16),
            conv("conv2_2", 16),
            pool("pool2"),
            conv("conv3_1", 32),
        ],
    }


NETWORKS = {
    "vgg16-prefix7": vgg16_prefix,
    "custom-4conv64": custom_4conv,
    "paper-example": paper_test_example,
    "tiny-vgg": tiny_vgg,
}


def layer_shapes(net):
    """shapes[i] = input shape of layer i; shapes[-1] = output shape."""
    s = (net["input"]["h"], net["input"]["w"], net["input"]["d"])
    shapes = [s]
    for layer in net["layers"]:
        h, w, d = s
        if layer["type"] == "conv":
            k = layer["kernel"]
            p = layer["padding"]
            s = (h + 2 * p - k + 1, w + 2 * p - k + 1, layer["filters"])
        else:
            win, st = layer["window"], layer["stride"]
            s = ((h - win) // st + 1, (w - win) // st + 1, d)
        shapes.append(s)
    return shapes


# ----------------------------------------------------------------------
# Parameters
# ----------------------------------------------------------------------

def init_params(net, seed):
    """He-initialized float32 parameters; list aligned with layers —
    (filters [k,kh,kw,c], bias [k]) for conv, None for pool."""
    rng = np.random.default_rng(seed)
    shapes = layer_shapes(net)
    params = []
    for i, layer in enumerate(net["layers"]):
        if layer["type"] == "conv":
            d = shapes[i][2]
            k, kern = layer["filters"], layer["kernel"]
            fan_in = kern * kern * d
            scale = np.sqrt(2.0 / fan_in)
            filt = rng.uniform(-scale, scale, size=(k, kern, kern, d))
            bias = rng.uniform(-0.01, 0.01, size=(k,))
            params.append((filt.astype(np.float32), bias.astype(np.float32)))
        else:
            params.append(None)
    return params


# ----------------------------------------------------------------------
# Group functions
# ----------------------------------------------------------------------

def group_forward(x, net, params, lo, hi, use_pallas=True):
    """Forward layers [lo, hi) — one fusion group. Consecutive conv pairs go
    through the fused Pallas kernel; stragglers use the single-layer kernels.
    """
    i = lo
    while i < hi:
        layer = net["layers"][i]
        if layer["type"] == "conv":
            nxt = net["layers"][i + 1] if i + 1 < hi else None
            if (
                use_pallas
                and nxt is not None
                and nxt["type"] == "conv"
                and layer["kernel"] == 3
                and nxt["kernel"] == 3
                and layer["stride"] == 1
                and nxt["stride"] == 1
            ):
                f1, b1 = params[i]
                f2, b2 = params[i + 1]
                x = fused_conv2(
                    x,
                    jnp.asarray(f1), jnp.asarray(b1),
                    jnp.asarray(f2), jnp.asarray(b2),
                    relu1=layer["relu"], relu2=nxt["relu"],
                )
                i += 2
                continue
            f, b = params[i]
            if use_pallas:
                x = conv3x3(
                    x, jnp.asarray(f), jnp.asarray(b),
                    padding=layer["padding"], relu=layer["relu"],
                )
            else:
                x = ref.conv2d_ref(
                    x, jnp.asarray(f), jnp.asarray(b),
                    padding=layer["padding"], relu=layer["relu"],
                )
            i += 1
        else:
            if use_pallas:
                x = maxpool(x, layer["window"], layer["stride"])
            else:
                x = ref.maxpool_ref(x, layer["window"], layer["stride"])
            i += 1
    return x


def full_forward(x, net, params, use_pallas=True):
    return group_forward(x, net, params, 0, len(net["layers"]), use_pallas)


def reference_forward(x, net, params):
    """Pure-jnp oracle for the whole network."""
    return ref.forward_ref(
        x,
        net["layers"],
        [
            (jnp.asarray(p[0]), jnp.asarray(p[1])) if p is not None else None
            for p in params
        ],
    )


def plan_groups(net, group_sizes):
    """[(lo, hi)] from group sizes; validates the partition."""
    n = len(net["layers"])
    assert all(s > 0 for s in group_sizes) and sum(group_sizes) == n, (
        f"bad plan {group_sizes} for {n} layers"
    )
    bounds, acc = [], 0
    for s in group_sizes:
        bounds.append((acc, acc + s))
        acc += s
    return bounds
