"""Layer-1 Pallas kernel: depth-concatenated 3x3 convolution.

FPGA -> TPU adaptation of the paper's architecture (DESIGN.md
SS-Hardware-Adaptation):

* the paper's *line buffer* (w-1 BRAM rows + window registers) becomes a
  kernel-row slab sliced per grid step from the padded input staged in VMEM —
  each step (one output row) touches only rows [i, i+kernel);
* *depth concatenation* (channels packed into one wide bus word) becomes the
  channel-minor HWC layout: one pixel's whole depth is contiguous, so the
  row's taps flatten into a single [ow, kernel*kernel*c] matrix;
* the paper's w*w*d DSP multipliers + LUT adder tree become ONE MXU
  contraction [ow, 9c] @ [9c, k] per row — the systolic array plays the role
  of the multiplier farm, the accumulation tree is implicit;
* the k filters that stream one-per-cycle through the FPGA pipeline are the
  k output columns of the same matmul;
* iterative depth decomposition (paper SS-V) is the contraction-dimension
  tiling XLA applies when 9c exceeds one MXU pass.

Kernels are lowered with interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and the artifacts must run from the rust runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_row_kernel(x_ref, w_ref, b_ref, o_ref, *, kernel, relu):
    """One grid step computes one output row.

    x_ref: [oh + kernel - 1, ow + kernel - 1, c]  (whole padded input; the
           step reads only its kernel-row line-buffer slab)
    w_ref: [kernel * kernel * c, k]  (tap-major, depth-minor — the
           depth-concatenated filter banks of the paper's Fig 4)
    b_ref: [k]
    o_ref: [1, ow, k]
    """
    i = pl.program_id(0)
    ow = o_ref.shape[1]
    # The line-buffer slab: kernel rows starting at output row i.
    slab = x_ref[pl.ds(i, kernel), :, :]
    # Window formation (paper Fig 2), vectorized over the row: for each tap
    # (dy, dx) take the width-ow slice starting at dx.
    taps = []
    for dy in range(kernel):
        for dx in range(kernel):
            taps.append(jax.lax.dynamic_slice_in_dim(slab[dy], dx, ow, axis=0))
    # Depth-concatenated im2col row: [ow, kernel*kernel*c].
    win = jnp.concatenate(taps, axis=-1)
    # The MXU contraction standing in for the DSP farm + adder tree.
    acc = jnp.dot(win, w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[0, :, :] = acc


def flatten_filters(filters):
    """Depth-concatenated filter layout (paper Fig 4): [k,kh,kw,c] ->
    tap-major [kh*kw*c, k] so im2col rows contract directly."""
    k, kh, kw, c = filters.shape
    return jnp.transpose(filters, (1, 2, 3, 0)).reshape(kh * kw * c, k)


def conv3x3(x, filters, bias, padding=1, relu=True, interpret=True):
    """Depth-concatenated same-conv via Pallas.

    x: [h, w, c]; filters: [k, kh, kw, c]; bias: [k] -> [oh, ow, k].
    """
    k, kh, kw, c = filters.shape
    assert kh == kw, "square kernels only"
    kernel = kh
    h, w, _ = x.shape
    oh = h + 2 * padding - kernel + 1
    ow = w + 2 * padding - kernel + 1

    # Zero padding folded in up front (the paper folds it into line-buffer
    # addressing, Fig 3); the kernel then runs a valid conv.
    xp = jnp.pad(x, ((padding, padding), (padding, padding), (0, 0)))
    wmat = flatten_filters(filters)

    return pl.pallas_call(
        functools.partial(_conv_row_kernel, kernel=kernel, relu=relu),
        grid=(oh,),
        in_specs=[
            pl.BlockSpec(xp.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(wmat.shape, lambda i: (0, 0)),
            pl.BlockSpec(bias.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, ow, k), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((oh, ow, k), jnp.float32),
        interpret=interpret,
    )(xp, wmat, bias)
