"""Layer-1 Pallas kernel: inter-layer fused conv->conv(->pool) block.

The paper's central claim is that fused layers exchange intermediates
entirely on chip. The TPU mapping: one `pallas_call` computes a row of the
*second* conv per grid step; the rows of the first conv it depends on are
produced inside the same kernel and live only in registers/VMEM — they are
never materialized to HBM, exactly as the paper's intermediate line buffer
never reaches DDR.

Two scheduling variants exist for the first conv's rows:

* **recompute** (this kernel): each step recomputes the `kernel` first-conv
  rows its window needs (the Alwani-style pyramid with per-row granularity —
  3x arithmetic on conv1, zero cross-step state);
* **carry** (the paper's line buffer): a VMEM scratch ring carries conv1 rows
  across sequential grid steps (TPU grids execute in order). Implemented in
  `fused_conv2_carry` below; both validate against the same reference, and
  the repo's benches compare their HLO op counts (DESIGN.md SS-Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .conv3x3 import flatten_filters


def _row_conv(slab, wmat, bias, ow, kernel, relu):
    """Valid-conv one output row from a [kernel, ow+kernel-1, c] slab."""
    taps = []
    for dy in range(kernel):
        for dx in range(kernel):
            taps.append(jax.lax.dynamic_slice_in_dim(slab[dy], dx, ow, axis=0))
    win = jnp.concatenate(taps, axis=-1)
    acc = jnp.dot(win, wmat, preferred_element_type=jnp.float32)
    acc = acc + bias[None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    return acc


def _fused2_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref, *,
                   kernel, relu1, relu2, mid_w):
    """Grid step i emits output row i of conv2.

    x_ref holds the twice-padded input. conv2 row i needs conv1 (padded)
    rows [i, i+kernel); conv1 row j needs x rows [j, j+kernel). The step
    computes those `kernel` conv1 rows in registers (recompute schedule) —
    the intermediate never leaves the chip.

    mid_w: width of a padded conv1 row (= conv2's ow + kernel - 1).
    """
    i = pl.program_id(0)
    ow = o_ref.shape[1]
    h_pad = x_ref.shape[0]  # h + 2 (once-padded input rows)
    n_mid = h_pad - 2  # conv1 real output rows (= h for same-conv)
    # conv2 row i needs conv1 rows [i-1, i+1] in real coordinates; rows -1
    # and n_mid are the zero padding, produced by masking.
    mid_rows = []
    for dy in range(kernel):
        r = i + dy - 1  # real conv1 row for this tap
        # conv1 row r reads padded-input rows [r, r+kernel); clamp the slab
        # start for the out-of-range taps, then mask their contribution.
        r_clamped = jnp.clip(r, 0, h_pad - kernel)
        slab = x_ref[pl.ds(r_clamped, kernel), :, :]
        row = _row_conv(slab, w1_ref[...], b1_ref[...], mid_w - (kernel - 1),
                        kernel, relu1)
        # Horizontal padding of the conv1 row for conv2's window.
        row = jnp.pad(row, ((1, 1), (0, 0)))
        valid = jnp.logical_and(r >= 0, r < n_mid)
        row = jnp.where(valid, row, jnp.zeros_like(row))
        mid_rows.append(row)
    mid_slab = jnp.stack(mid_rows)  # [kernel, mid_w, k1]
    out = _row_conv(mid_slab, w2_ref[...], b2_ref[...], ow, kernel, relu2)
    o_ref[0, :, :] = out


def fused_conv2(x, f1, b1, f2, b2, relu1=True, relu2=True, interpret=True):
    """Fused conv3x3 -> conv3x3 (both same-padding stride 1) in one kernel.

    x: [h, w, c]; f1: [k1, 3, 3, c]; f2: [k2, 3, 3, k1] -> [h, w, k2].
    """
    k1, kernel, _, c = f1.shape
    k2 = f2.shape[0]
    assert f2.shape[3] == k1, "fused depth mismatch"
    h, w, _ = x.shape

    xp = jnp.pad(x, ((1, 1), (1, 1), (0, 0)))
    w1 = flatten_filters(f1)
    w2 = flatten_filters(f2)
    mid_w = w + 2  # padded conv1 row width

    return pl.pallas_call(
        functools.partial(
            _fused2_kernel,
            kernel=kernel,
            relu1=relu1,
            relu2=relu2,
            mid_w=mid_w,
        ),
        grid=(h,),
        in_specs=[
            pl.BlockSpec(xp.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(w1.shape, lambda i: (0, 0)),
            pl.BlockSpec(b1.shape, lambda i: (0,)),
            pl.BlockSpec(w2.shape, lambda i: (0, 0)),
            pl.BlockSpec(b2.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, w, k2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w, k2), jnp.float32),
        interpret=interpret,
    )(xp, w1, b1, w2, b2)


def _fused2_carry_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref,
                         ring_ref, *, kernel, relu1, relu2, mid_w):
    """Carry-schedule variant: a VMEM scratch ring holds the last `kernel`
    conv1 rows across grid steps — the literal analogue of the paper's
    intermediate line buffer. Step i:

      * computes conv1 padded row i+kernel-1 into ring slot (i+kernel-1)%kernel
        (steps 0 fills the initial kernel rows, like the fill latency);
      * emits conv2 row i from the ring.
    """
    i = pl.program_id(0)
    ow = o_ref.shape[1]
    h_pad = x_ref.shape[0]  # h + 2
    n_mid = h_pad - 2  # conv1 real output rows

    def conv1_padded_row(p):
        r = p - 1  # real conv1 row for padded index p
        r_clamped = jnp.clip(r, 0, h_pad - kernel)
        slab = x_ref[pl.ds(r_clamped, kernel), :, :]
        row = _row_conv(slab, w1_ref[...], b1_ref[...], mid_w - (kernel - 1),
                        kernel, relu1)
        row = jnp.pad(row, ((1, 1), (0, 0)))
        valid = jnp.logical_and(r >= 0, r < n_mid)
        return jnp.where(valid, row, jnp.zeros_like(row))

    # Fill the ring at step 0 (rows 0..kernel-1), then one new row per step.
    @pl.when(i == 0)
    def _fill():
        for p in range(kernel):
            ring_ref[p, :, :] = conv1_padded_row(jnp.int32(p))

    @pl.when(i > 0)
    def _advance():
        p = i + kernel - 1
        ring_ref[p % kernel, :, :] = conv1_padded_row(p)

    # Gather the window rows i..i+kernel-1 from the ring in order.
    rows = []
    for dy in range(kernel):
        p = i + dy
        rows.append(ring_ref[p % kernel, :, :])
    mid_slab = jnp.stack(rows)
    o_ref[0, :, :] = _row_conv(mid_slab, w2_ref[...], b2_ref[...], ow,
                               kernel, relu2)


def fused_conv2_carry(x, f1, b1, f2, b2, relu1=True, relu2=True,
                      interpret=True):
    """Line-buffer-carry variant of `fused_conv2` (VMEM scratch ring)."""
    k1, kernel, _, c = f1.shape
    k2 = f2.shape[0]
    assert f2.shape[3] == k1
    h, w, _ = x.shape
    xp = jnp.pad(x, ((1, 1), (1, 1), (0, 0)))
    w1 = flatten_filters(f1)
    w2 = flatten_filters(f2)
    mid_w = w + 2

    return pl.pallas_call(
        functools.partial(
            _fused2_carry_kernel,
            kernel=kernel,
            relu1=relu1,
            relu2=relu2,
            mid_w=mid_w,
        ),
        grid=(h,),
        in_specs=[
            pl.BlockSpec(xp.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(w1.shape, lambda i: (0, 0)),
            pl.BlockSpec(b1.shape, lambda i: (0,)),
            pl.BlockSpec(w2.shape, lambda i: (0, 0)),
            pl.BlockSpec(b2.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, w, k2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w, k2), jnp.float32),
        scratch_shapes=[_vmem_scratch((kernel, mid_w, k1))],
        interpret=interpret,
    )(xp, w1, b1, w2, b2)


def _vmem_scratch(shape):
    """VMEM scratch allocation (the paper's intermediate line buffer).

    On real TPU this is `pltpu.VMEM`; interpret mode accepts the same spec.
    """
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)
