"""Pure-jnp reference oracles (the numeric ground truth of the repo).

Every Pallas kernel, every per-group jitted model, and (through the exported
golden tensors) the rust fixed-point simulator are validated against these
functions. Layout convention matches the rust side: feature maps are HWC,
filter banks are [k, kh, kw, c] ("KHWC").
"""

import jax
import jax.numpy as jnp


def conv2d_ref(x, filters, bias, padding=1, relu=True):
    """2-D convolution over an HWC volume, stride 1.

    x: [h, w, c]; filters: [k, kh, kw, c]; bias: [k] -> [oh, ow, k].
    """
    k, kh, kw, c = filters.shape
    assert x.shape[-1] == c, f"depth mismatch {x.shape} vs {filters.shape}"
    lhs = x[None]  # [1, h, w, c]
    rhs = jnp.transpose(filters, (1, 2, 3, 0))  # HWIO
    out = jax.lax.conv_general_dilated(
        lhs,
        rhs,
        window_strides=(1, 1),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )[0]
    out = out + bias[None, None, :]
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def maxpool_ref(x, window=2, stride=2):
    """Max pooling over an HWC volume (floor semantics, like the paper)."""
    h, w, _ = x.shape
    oh = (h - window) // stride + 1
    ow = (w - window) // stride + 1
    x = x[: (oh - 1) * stride + window, : (ow - 1) * stride + window]
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(window, window, 1),
        window_strides=(stride, stride, 1),
        padding="VALID",
    )


def forward_ref(x, layers, params):
    """Run a whole layer list.

    layers: list of dicts mirroring the rust Network JSON:
      {"type": "conv", "padding": p, "relu": bool} or
      {"type": "maxpool", "window": w, "stride": s}
    params: aligned with layers; (filters, bias) for conv, None for pool.
    """
    for spec, p in zip(layers, params):
        if spec["type"] == "conv":
            x = conv2d_ref(
                x, p[0], p[1],
                padding=spec.get("padding", 1),
                relu=spec.get("relu", True),
            )
        elif spec["type"] == "maxpool":
            x = maxpool_ref(x, spec["window"], spec["stride"])
        else:
            raise ValueError(f"unknown layer type {spec['type']}")
    return x
