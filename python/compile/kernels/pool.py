"""Layer-1 Pallas kernel: max pooling with the paper's pool line buffer.

The FPGA design (paper SS-III-D) redirects conv outputs into a pool row
buffer, replacing entries with running maxima, and emits a pooled row once
its `window` input rows have streamed past. On TPU the analogue is: one grid
step per pooled row, reading the `window`-row slab and reducing laneswise —
the depth-concatenated word pools elementwise across lanes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pool_row_kernel(x_ref, o_ref, *, window, stride):
    """x_ref: [h, w, c] (full volume; the step reads its window-row slab);
    o_ref: [1, ow, c]."""
    j = pl.program_id(0)
    ow = o_ref.shape[1]
    c = o_ref.shape[2]
    slab = x_ref[pl.ds(j * stride, window), :, :]  # [window, w, c]
    # Column phase p of the pooled window: rows are already gathered; take
    # strided column slices and fold with running max (the paper's even/odd
    # address update generalized).
    acc = jnp.full((ow, c), -jnp.inf, dtype=jnp.float32)
    for dy in range(window):
        row = slab[dy]
        for dx in range(window):
            cols = jax.lax.slice_in_dim(row, dx, dx + (ow - 1) * stride + 1, stride=stride, axis=0)
            acc = jnp.maximum(acc, cols)
    o_ref[0, :, :] = acc


def maxpool(x, window=2, stride=2, interpret=True):
    """Max-pool an HWC volume: [h, w, c] -> [oh, ow, c]."""
    h, w, c = x.shape
    oh = (h - window) // stride + 1
    ow = (w - window) // stride + 1
    return pl.pallas_call(
        functools.partial(_pool_row_kernel, window=window, stride=stride),
        grid=(oh,),
        in_specs=[pl.BlockSpec(x.shape, lambda j: (0, 0, 0))],
        out_specs=pl.BlockSpec((1, ow, c), lambda j: (j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((oh, ow, c), jnp.float32),
        interpret=interpret,
    )(x)
