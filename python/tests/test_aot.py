"""AOT path: HLO-text lowering, manifest structure, golden vectors."""

import json
import os

import numpy as np
import jax.numpy as jnp

from compile import aot, model


def test_to_hlo_text_shape():
    net = model.paper_test_example()
    params = model.init_params(net, aot.WEIGHT_SEED)
    lowered = aot.lower_group(net, params, 0, 3)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    # single input param f32[5,5,3], tuple output f32[2,2,3]
    assert "f32[5,5,3]" in text
    assert "f32[2,2,3]" in text


def test_group_lowering_is_deterministic():
    net = model.paper_test_example()
    params = model.init_params(net, aot.WEIGHT_SEED)
    t1 = aot.to_hlo_text(aot.lower_group(net, params, 0, 2))
    t2 = aot.to_hlo_text(aot.lower_group(net, params, 0, 2))
    assert t1 == t2


def test_build_net_manifest_and_golden(tmp_path):
    out = str(tmp_path)
    entry = aot.build_net("paper-example", out)
    net_dir = os.path.join(out, "paper-example")

    # Weights round-trip.
    for w in entry["weights"]:
        filt = np.fromfile(os.path.join(net_dir, w["filter"]), dtype=np.float32)
        assert filt.size == int(np.prod(w["filter_shape"]))
        bias = np.fromfile(os.path.join(net_dir, w["bias"]), dtype=np.float32)
        assert bias.size == w["bias_shape"][0]

    # Golden output equals a fresh reference forward of the golden input.
    g = entry["golden"]
    x = np.fromfile(os.path.join(net_dir, g["input"]), dtype=np.float32).reshape(
        g["input_shape"]
    )
    y = np.fromfile(os.path.join(net_dir, g["output"]), dtype=np.float32).reshape(
        g["output_shape"]
    )
    net = model.paper_test_example()
    params = model.init_params(net, entry["weight_seed"])
    want = np.asarray(model.reference_forward(jnp.asarray(x), net, params))
    np.testing.assert_allclose(y, want, atol=1e-5)

    # Plans cover the network.
    for plan in entry["plans"].values():
        assert sum(plan["group_sizes"]) == len(net["layers"])
        for group in plan["groups"]:
            path = os.path.join(net_dir, group["hlo"])
            assert os.path.exists(path)
            with open(path) as f:
                assert f.read().startswith("HloModule")


def test_manifest_json_serializable(tmp_path):
    out = str(tmp_path)
    entry = aot.build_net("paper-example", out)
    s = json.dumps({"networks": {"paper-example": entry}}, sort_keys=True)
    back = json.loads(s)
    assert back["networks"]["paper-example"]["weight_seed"] == aot.WEIGHT_SEED
