"""L1 correctness: every Pallas kernel against the pure-jnp oracle.

Hypothesis sweeps shapes/depths/filter counts; assert_allclose against
ref.py is the repo's core numeric signal (DESIGN.md §Validation-chain #2).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import conv2d_ref, maxpool_ref
from compile.kernels.conv3x3 import conv3x3, flatten_filters
from compile.kernels.pool import maxpool
from compile.kernels.fused_block import fused_conv2, fused_conv2_carry


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


SETTINGS = dict(max_examples=20, deadline=None)


@settings(**SETTINGS)
@given(
    h=st.integers(3, 12),
    w=st.integers(3, 12),
    c=st.integers(1, 8),
    k=st.integers(1, 8),
    relu=st.booleans(),
    seed=st.integers(0, 2**31),
)
def test_conv3x3_matches_ref(h, w, c, k, relu, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, h, w, c)
    f = rand(rng, k, 3, 3, c)
    b = rand(rng, k)
    got = conv3x3(x, f, b, padding=1, relu=relu)
    want = conv2d_ref(x, f, b, padding=1, relu=relu)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-4)


@settings(**SETTINGS)
@given(
    h=st.integers(2, 13),
    w=st.integers(2, 13),
    c=st.integers(1, 8),
    seed=st.integers(0, 2**31),
)
def test_maxpool_matches_ref(h, w, c, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, h, w, c)
    got = maxpool(x, 2, 2)
    want = maxpool_ref(x, 2, 2)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=0)


@settings(**SETTINGS)
@given(
    h=st.integers(3, 10),
    w=st.integers(3, 10),
    c=st.integers(1, 5),
    k1=st.integers(1, 5),
    k2=st.integers(1, 5),
    seed=st.integers(0, 2**31),
)
def test_fused_conv2_matches_composed_ref(h, w, c, k1, k2, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, h, w, c)
    f1, b1 = rand(rng, k1, 3, 3, c), rand(rng, k1)
    f2, b2 = rand(rng, k2, 3, 3, k1), rand(rng, k2)
    want = conv2d_ref(conv2d_ref(x, f1, b1), f2, b2)
    got = fused_conv2(x, f1, b1, f2, b2)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-3)


@settings(**SETTINGS)
@given(
    h=st.integers(3, 9),
    w=st.integers(3, 9),
    c=st.integers(1, 4),
    k1=st.integers(1, 4),
    k2=st.integers(1, 4),
    seed=st.integers(0, 2**31),
)
def test_fused_carry_matches_recompute(h, w, c, k1, k2, seed):
    """The line-buffer-carry schedule must be numerically identical to the
    recompute schedule (same arithmetic, different movement)."""
    rng = np.random.default_rng(seed)
    x = rand(rng, h, w, c)
    f1, b1 = rand(rng, k1, 3, 3, c), rand(rng, k1)
    f2, b2 = rand(rng, k2, 3, 3, k1), rand(rng, k2)
    a = fused_conv2(x, f1, b1, f2, b2)
    b = fused_conv2_carry(x, f1, b1, f2, b2)
    np.testing.assert_allclose(np.array(a), np.array(b), atol=1e-5)


def test_conv_zero_padding_rows():
    """Border windows must see zeros (paper Fig 3): an input of ones with an
    all-ones 3x3x1 filter gives 4 at corners, 6 at edges, 9 inside."""
    x = jnp.ones((5, 5, 1))
    f = jnp.ones((1, 3, 3, 1))
    b = jnp.zeros((1,))
    out = np.array(conv3x3(x, f, b, relu=False))[:, :, 0]
    assert out[0, 0] == 4 and out[0, 4] == 4 and out[4, 0] == 4
    assert out[0, 2] == 6 and out[2, 0] == 6
    assert out[2, 2] == 9


def test_relu_clamps():
    rng = np.random.default_rng(3)
    x = rand(rng, 6, 6, 2)
    f, b = rand(rng, 3, 3, 3, 2), rand(rng, 3)
    out = np.array(conv3x3(x, f, b, relu=True))
    assert (out >= 0).all()


def test_flatten_filters_layout():
    """Tap-major, depth-minor — the depth-concatenated banks of Fig 4."""
    k, c = 2, 3
    f = np.arange(k * 3 * 3 * c, dtype=np.float32).reshape(k, 3, 3, c)
    w = np.array(flatten_filters(jnp.asarray(f)))
    assert w.shape == (9 * c, k)
    for ky in range(3):
        for kx in range(3):
            for ch in range(c):
                for kk in range(k):
                    assert w[(ky * 3 + kx) * c + ch, kk] == f[kk, ky, kx, ch]


@pytest.mark.parametrize("hw", [(3, 3), (4, 7), (16, 16)])
def test_fused_extreme_shapes(hw):
    h, w = hw
    rng = np.random.default_rng(11)
    x = rand(rng, h, w, 2)
    f1, b1 = rand(rng, 3, 3, 3, 2), rand(rng, 3)
    f2, b2 = rand(rng, 2, 3, 3, 3), rand(rng, 2)
    want = conv2d_ref(conv2d_ref(x, f1, b1), f2, b2)
    got = fused_conv2(x, f1, b1, f2, b2)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-4)
