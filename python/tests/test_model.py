"""L2 correctness: per-group jitted model vs the whole-net reference
(DESIGN.md §Validation-chain #3), plus spec/shape plumbing."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model


def sample(net, seed=0):
    rng = np.random.default_rng(seed)
    h, w, d = net["input"]["h"], net["input"]["w"], net["input"]["d"]
    return jnp.asarray(rng.uniform(-1, 1, size=(h, w, d)).astype(np.float32))


def test_vgg_prefix_shapes():
    net = model.vgg16_prefix()
    shapes = model.layer_shapes(net)
    assert shapes[0] == (224, 224, 3)
    assert shapes[1] == (224, 224, 64)
    assert shapes[3] == (112, 112, 64)
    assert shapes[5] == (112, 112, 128)
    assert shapes[6] == (56, 56, 128)
    assert shapes[7] == (56, 56, 256)


def test_params_deterministic():
    net = model.tiny_vgg()
    a = model.init_params(net, 42)
    b = model.init_params(net, 42)
    for pa, pb in zip(a, b):
        if pa is None:
            assert pb is None
        else:
            assert (pa[0] == pb[0]).all() and (pa[1] == pb[1]).all()
    c = model.init_params(net, 43)
    assert not (a[0][0] == c[0][0]).all()


@pytest.mark.parametrize("plan", [[7], [1] * 7, [2, 3, 2], [3, 2, 2]])
def test_grouped_forward_matches_reference_tiny(plan):
    net = model.tiny_vgg()
    params = model.init_params(net, 1)
    x = sample(net)
    want = np.array(model.reference_forward(x, net, params))
    cur = x
    for lo, hi in model.plan_groups(net, plan):
        cur = model.group_forward(cur, net, params, lo, hi)
    np.testing.assert_allclose(np.array(cur), want, atol=2e-3)


def test_paper_example_forward():
    net = model.paper_test_example()
    params = model.init_params(net, 2)
    x = sample(net, 5)
    got = model.full_forward(x, net, params)
    want = model.reference_forward(x, net, params)
    assert got.shape == (2, 2, 3)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_custom_net_random_groupings(seed):
    """Any contiguous grouping computes the same function."""
    rng = np.random.default_rng(seed)
    net = model.tiny_vgg()
    params = model.init_params(net, 3)
    x = sample(net, seed % 1000)
    # random partition of 7 layers
    sizes, left = [], 7
    while left > 0:
        s = int(rng.integers(1, left + 1))
        sizes.append(s)
        left -= s
    want = np.array(model.reference_forward(x, net, params))
    cur = x
    for lo, hi in model.plan_groups(net, sizes):
        cur = model.group_forward(cur, net, params, lo, hi)
    np.testing.assert_allclose(np.array(cur), want, atol=2e-3)


def test_plan_groups_validation():
    net = model.tiny_vgg()
    assert model.plan_groups(net, [7]) == [(0, 7)]
    assert model.plan_groups(net, [2, 5]) == [(0, 2), (2, 7)]
    with pytest.raises(AssertionError):
        model.plan_groups(net, [3, 3])
    with pytest.raises(AssertionError):
        model.plan_groups(net, [0, 7])


def test_network_registry():
    for name, builder in model.NETWORKS.items():
        net = builder()
        assert net["name"] == name
        assert len(net["layers"]) >= 1
        shapes = model.layer_shapes(net)
        assert all(all(v > 0 for v in s) for s in shapes)
