//! Energy report: the paper's per-watt motivation quantified — per-inference
//! energy of DeCoILFNet across fusion plans, with the off-chip share that
//! the paper's traffic argument is really about.
//!
//! Run: `cargo run --release --example energy_report`

use decoilfnet::accel::fusion::fig7_points;
use decoilfnet::accel::{Engine, Weights};
use decoilfnet::config::{vgg16_prefix, AccelConfig};
use decoilfnet::resources::energy::{inference_energy, EnergyModel};
use decoilfnet::util::table::Table;

fn main() {
    let cfg = AccelConfig::paper_default();
    let net = vgg16_prefix();
    let weights = Weights::random(&net, 1);
    let engine = Engine::new(cfg.clone());
    let model = EnergyModel::fpga_28nm();

    let mut t = Table::new(&[
        "point",
        "plan",
        "compute mJ",
        "on-chip mJ",
        "off-chip mJ",
        "static mJ",
        "total mJ",
        "off-chip share",
    ])
    .title("Per-inference energy across the Fig 7 fusion sweep (28 nm constants)")
    .label_col();

    let mut first_total = 0.0;
    let mut last_total = 0.0;
    for (label, plan) in fig7_points(&net) {
        let rep = engine.simulate(&net, &weights, &plan);
        let e = inference_energy(&model, &net, &rep, cfg.platform.freq_mhz);
        t.row(&[
            label.to_string(),
            plan.label(),
            format!("{:.1}", e.compute_mj),
            format!("{:.1}", e.on_chip_mj),
            format!("{:.1}", e.off_chip_mj),
            format!("{:.1}", e.static_mj),
            format!("{:.1}", e.total_mj()),
            format!("{:.1}%", 100.0 * e.off_chip_fraction()),
        ]);
        if label == 'A' {
            first_total = e.total_mj();
        }
        if label == 'G' {
            last_total = e.total_mj();
        }
    }
    println!("{}", t.to_ascii());
    println!(
        "full fusion saves {:.0}% of per-inference energy vs no fusion — \
         almost entirely off-chip traffic and serialization time.",
        100.0 * (1.0 - last_total / first_total)
    );
    assert!(last_total < first_total);

    // Throughput-normalized: energy per frame at steady state (streaming).
    let (_, interval) = engine.simulate_stream(
        &net,
        &weights,
        &decoilfnet::accel::FusionPlan::fully_fused(7),
        16,
    );
    let fps = cfg.platform.freq_mhz * 1e6 / interval;
    println!(
        "steady-state serving: {:.1} fps at 120 MHz → {:.2} J/s ≈ {:.1} W effective",
        fps,
        last_total / 1e3 * fps,
        last_total / 1e3 * fps
    );
}
