//! Table III reproduction driver: the paper's custom network of four
//! consecutive 64-filter 3×3 convolutions — the best case for inter-layer
//! fusion (no pooling to drain the pipeline).
//!
//! Run: `cargo run --release --example consecutive_conv`

use decoilfnet::accel::{Engine, FusionPlan, Weights};
use decoilfnet::baselines::cpu_ref::{forward_timed, CpuWeights};
use decoilfnet::config::{custom_4conv, AccelConfig, Network};
use decoilfnet::tensor::NdTensor;
use decoilfnet::util::table::{fmt_speedup, Table};

/// Paper Table III: (ending layer, CPU ms, GPU ms, DeCoILFNet ms).
const PAPER: &[(&str, f64, f64, f64)] = &[
    ("conv_1", 114.54, 23.12, 26.764),
    ("conv_2", 736.78, 27.42, 27.01),
    ("conv_3", 1346.32, 35.45, 27.24),
    ("conv_4", 2113.24, 38.58, 27.48),
];

fn main() {
    let cfg = AccelConfig::paper_default();
    let full = custom_4conv();
    let engine = Engine::new(cfg.clone());

    println!("measuring CPU reference ...");
    let cpu_w = CpuWeights::random(&full, 1);
    let input = NdTensor::random(&full.input.as_slice(), 7, -1.0, 1.0);
    let (_, cpu_cum) = forward_timed(&full, &cpu_w, &input);

    let mut t = Table::new(&[
        "ending layer",
        "CPU meas (ms)",
        "DeCoILF sim (ms)",
        "speedup",
        "paper speedup",
    ])
    .title("Table III — four consecutive conv-64 layers")
    .label_col();

    let mut prev_ms = 0.0;
    for (i, layer) in full.layers.iter().enumerate() {
        let prefix = Network {
            name: format!("4conv[..={}]", layer.name()),
            input: full.input,
            layers: full.layers[..=i].to_vec(),
        };
        let w = Weights::random(&prefix, 1);
        let rep = engine.simulate(&prefix, &w, &FusionPlan::fully_fused(i + 1));
        let ours_ms = rep.ms_at(cfg.platform.freq_mhz);
        let cpu_ms = cpu_cum[i].1;
        let (pname, pcpu, _pgpu, pours) = PAPER[i];
        assert_eq!(pname, layer.name());
        t.row(&[
            layer.name().to_string(),
            format!("{cpu_ms:.1}"),
            format!("{ours_ms:.2}"),
            fmt_speedup(cpu_ms / ours_ms),
            fmt_speedup(pcpu / pours),
        ]);
        // The paper's key observation: each fused conv adds only fill
        // latency, so cumulative time is nearly flat after conv_1.
        if i > 0 {
            let delta = ours_ms - prev_ms;
            assert!(
                delta < 2.0,
                "fused conv_{} added {delta:.2} ms — pipeline must stay flat",
                i + 1
            );
        }
        prev_ms = ours_ms;
    }
    println!("{}", t.to_ascii());
    println!("key property: DeCoILFNet's cumulative time is nearly flat across fused convs");
    println!("(the paper's 26.76 → 27.48 ms); CPU time grows linearly with depth.");
}
