//! Perf-pass driver: times the repo's own hot paths in isolation.
use decoilfnet::accel::{Engine, FusionPlan, Weights};
use decoilfnet::config::{tiny_vgg, vgg16_full, vgg16_prefix, AccelConfig};
use decoilfnet::tensor::NdTensor;
use decoilfnet::util::bench::{e2e_config, Bencher};

fn main() {
    let cfg = AccelConfig::paper_default();
    let e = Engine::new(cfg.clone());
    let mut b = Bencher::with_config(e2e_config());

    // L3 hot path 1: the timestamp engine.
    let vgg = vgg16_prefix();
    let wv = Weights::random(&vgg, 1);
    b.bench("simulate vgg7 fused", || e.simulate(&vgg, &wv, &FusionPlan::fully_fused(7)));
    let full = vgg16_full();
    let wf = Weights::random(&full, 1);
    b.bench("simulate vgg-full18 fused", || {
        e.simulate(&full, &wf, &FusionPlan::fully_fused(18))
    });

    // L3 hot path 2: the functional fixed-point forward (verify/e2e path).
    let tiny = tiny_vgg();
    let wt = Weights::random(&tiny, 1);
    let input = NdTensor::random(&tiny.input.as_slice(), 7, -1.0, 1.0);
    b.bench("forward_fx tiny-vgg", || e.forward_fx(&tiny, &wt, &input));
}
