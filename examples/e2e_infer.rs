//! End-to-end driver (DESIGN.md E8): every layer of the system composes on a
//! real small workload.
//!
//!   artifacts (python/JAX/Pallas, built once) → rust PJRT runtime →
//!   coordinator serving batched requests → numerics cross-checked against
//!   the Q16.16 cycle-accurate simulator → hardware metrics (cycles, ms,
//!   DDR traffic, resources) reported for the paper's VGG-16 workload.
//!
//! Requires `make artifacts`. Run: `cargo run --release --example e2e_infer`
//! The run is recorded in EXPERIMENTS.md §E8.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use decoilfnet::accel::{Engine, Weights};
use decoilfnet::config::{vgg16_prefix, AccelConfig};
use decoilfnet::coordinator::{best_plan, BatchPolicy, Objective, Server, ServerConfig};
use decoilfnet::resources::{plan_resources, utilization};
use decoilfnet::runtime::Runtime;
use decoilfnet::tensor::NdTensor;
use decoilfnet::util::prng::Rng;
use decoilfnet::util::stats::fmt_count;
use decoilfnet::verify::{verify_plan, DEFAULT_TOLERANCE};

fn main() -> anyhow::Result<()> {
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let cfg = AccelConfig::paper_default();

    // ---- 1. Numeric verification: simulator vs PJRT on fresh random inputs.
    println!("== step 1: simulator ↔ runtime verification (tiny-vgg) ==");
    let rt = Runtime::load(&artifacts, "tiny-vgg")?;
    let mut rng = Rng::new(2024);
    for trial in 0..3 {
        let mut input = NdTensor::zeros(&rt.entry.network.input.as_slice());
        rng.fill_f32(input.data_mut(), -1.0, 1.0);
        let rep = verify_plan(&rt, &cfg, "fused", &input, DEFAULT_TOLERANCE)?;
        println!(
            "  trial {trial}: max |sim − runtime| = {:.2e} (tol {:.0e}) → {}",
            rep.max_abs_diff,
            rep.tolerance,
            if rep.passed { "PASS" } else { "FAIL" }
        );
        assert!(rep.passed);
    }

    // ---- 2. Serve a batched workload through the coordinator.
    println!("\n== step 2: batched serving over PJRT (48 requests, 4 clients) ==");
    let srv = Server::start(ServerConfig {
        artifacts_dir: artifacts.clone(),
        network: "tiny-vgg".into(),
        default_plan: "fused".into(),
        batch: BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        },
    })?;
    let (golden_in, golden_out) = rt.golden()?;
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for _ in 0..4 {
        let h = srv.handle.clone();
        let input = golden_in.clone();
        let want = golden_out.clone();
        joins.push(std::thread::spawn(move || {
            for _ in 0..12 {
                let resp = h.submit(input.clone(), None).wait().unwrap();
                let out = resp.result.unwrap();
                assert!(out.max_abs_diff(&want) < 1e-3);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = srv.handle.metrics();
    println!(
        "  {} responses, {} batches (mean size {:.1}), {:.1} req/s, 0 errors: {}",
        m.responses,
        m.batches,
        m.mean_batch_size(),
        48.0 / wall,
        if m.errors == 0 { "PASS" } else { "FAIL" }
    );
    assert_eq!(m.errors, 0);
    srv.shutdown();

    // ---- 3. Hardware metrics for the paper's workload (VGG-16 prefix).
    println!("\n== step 3: DeCoILFNet hardware metrics (VGG-16 first 7 layers) ==");
    let net = vgg16_prefix();
    let weights = Weights::random(&net, 1);
    let engine = Engine::new(cfg.clone());
    let plan = best_plan(&cfg, &net, &weights, Objective::Latency)
        .expect("a feasible plan must exist")
        .plan;
    let rep = engine.simulate(&net, &weights, &plan);
    let res = plan_resources(&cfg, &net, &plan);
    let u = utilization(res, &cfg);
    println!("  planner choice: {}", plan.label());
    println!(
        "  {} cycles = {:.2} ms @ {} MHz   (paper: 5,034k cycles = 41.95 ms)",
        fmt_count(rep.total_cycles),
        rep.ms_at(cfg.platform.freq_mhz),
        cfg.platform.freq_mhz
    );
    println!(
        "  DDR traffic {:.2} MB (paper: 6.69 MB)   weights preload {} cycles",
        rep.total_mb(),
        fmt_count(rep.weight_load_cycles)
    );
    println!(
        "  resources: {} DSP ({:.1}%), {} BRAM36 ({:.1}%), {} LUT ({:.1}%), {} FF ({:.1}%)",
        res.dsp, u.dsp_pct, res.bram36(), u.bram_pct, res.lut, u.lut_pct, res.ff, u.ff_pct
    );

    println!("\ne2e OK — all layers composed: artifacts → runtime → coordinator → simulator.");
    Ok(())
}
