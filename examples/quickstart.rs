//! Quickstart: the three things DeCoILFNet does, in 60 lines.
//!
//!   1. simulate a fused VGG-like network cycle-accurately,
//!   2. compare fusion against the unfused baseline,
//!   3. check the fixed-point datapath against a float reference.
//!
//! Run: `cargo run --release --example quickstart`

use decoilfnet::accel::{Engine, FusionPlan, Weights};
use decoilfnet::baselines::cpu_ref::{self, CpuWeights};
use decoilfnet::config::{tiny_vgg, AccelConfig};
use decoilfnet::tensor::NdTensor;

fn main() {
    let cfg = AccelConfig::paper_default();
    let net = tiny_vgg();
    let n = net.layers.len();
    println!("network: {} ({} layers, input {:?})", net.name, n, net.input.as_slice());

    // 1. Cycle-accurate simulation, fully fused (the paper's architecture).
    let weights = Weights::random(&net, 1);
    let engine = Engine::new(cfg.clone());
    let fused = engine.simulate(&net, &weights, &FusionPlan::fully_fused(n));
    println!(
        "fused:   {:>10} cycles = {:.3} ms @ {} MHz, {:.3} MB off-chip",
        fused.total_cycles,
        fused.ms_at(cfg.platform.freq_mhz),
        cfg.platform.freq_mhz,
        fused.total_mb()
    );

    // 2. The unfused baseline: every layer round-trips through DDR.
    let unfused = engine.simulate(&net, &weights, &FusionPlan::unfused(n));
    println!(
        "unfused: {:>10} cycles = {:.3} ms, {:.3} MB off-chip",
        unfused.total_cycles,
        unfused.ms_at(cfg.platform.freq_mhz),
        unfused.total_mb()
    );
    println!(
        "fusion wins {:.2}X on cycles and {:.2}X on traffic",
        unfused.total_cycles as f64 / fused.total_cycles as f64,
        unfused.total_mb() / fused.total_mb()
    );

    // 3. Functional check: Q16.16 datapath vs an f32 CPU reference built
    //    from the same seed.
    let input = NdTensor::random(&net.input.as_slice(), 7, -1.0, 1.0);
    let fx_out = engine.forward_fx(&net, &weights, &input).to_f32();
    let cpu_out = cpu_ref::forward(&net, &CpuWeights::random(&net, 1), &input);
    let diff = fx_out.max_abs_diff(&cpu_out);
    println!("fixed-point vs float: max |diff| = {diff:.2e}");
    assert!(diff < 2e-2, "datapath mismatch");
    println!("quickstart OK");
}
