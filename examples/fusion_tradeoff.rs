//! Fig 7 reproduction driver: the fusion-grouping trade-off between off-chip
//! data volume and DSP utilization, swept over the named points A…G plus the
//! full 64-plan design space via the coordinator's planner.
//!
//! Run: `cargo run --release --example fusion_tradeoff`

use decoilfnet::accel::fusion::fig7_points;
use decoilfnet::accel::latency::plan_traffic_bytes;
use decoilfnet::accel::Weights;
use decoilfnet::config::{vgg16_prefix, AccelConfig};
use decoilfnet::coordinator::{best_plan, cost_all_plans, Objective};
use decoilfnet::resources::plan_resources;
use decoilfnet::util::table::Table;

fn main() {
    let cfg = AccelConfig::paper_default();
    let net = vgg16_prefix();
    let weights = Weights::random(&net, 1);

    // The paper's A..G prefix-fusion sweep.
    let mut t = Table::new(&["point", "plan", "groups", "DDR MB", "DSP", "BRAM36"])
        .title("Fig 7 — fusion grouping vs off-chip traffic and DSP (A = unfused … G = all fused)")
        .label_col();
    let mut prev_mb = f64::INFINITY;
    let mut prev_dsp = 0;
    for (label, plan) in fig7_points(&net) {
        let mb = plan_traffic_bytes(&cfg, &net, &weights, &plan) as f64 / (1024.0 * 1024.0);
        let res = plan_resources(&cfg, &net, &plan);
        t.row(&[
            label.to_string(),
            plan.label(),
            plan.n_groups().to_string(),
            format!("{mb:.2}"),
            res.dsp.to_string(),
            res.bram36().to_string(),
        ]);
        assert!(mb <= prev_mb, "traffic must fall along A→G");
        assert!(res.dsp >= prev_dsp, "DSP must rise along A→G");
        prev_mb = mb;
        prev_dsp = res.dsp;
    }
    println!("{}", t.to_ascii());
    println!("paper's anchors: point A moves 23.54 MB of intermediates; point G moves none.\n");

    // The full design space through the planner.
    let costs = cost_all_plans(&cfg, &net, &weights);
    let feasible = costs.iter().filter(|c| c.fits).count();
    println!("design space: {} contiguous plans, {} feasible on the XC7V690T", costs.len(), feasible);
    for obj in [Objective::Latency, Objective::Traffic, Objective::LatencyUnderDspCap(20)] {
        match best_plan(&cfg, &net, &weights, obj) {
            Some(p) => println!(
                "  {:?} → {} ({} kcycles, {:.2} MB, {} DSP)",
                obj,
                p.plan.label(),
                p.cycles / 1000,
                p.traffic_bytes as f64 / (1024.0 * 1024.0),
                p.resources.dsp
            ),
            None => println!("  {obj:?} → no feasible plan"),
        }
    }
}
