//! Table II reproduction driver: cumulative timing of the first seven
//! VGG-16 layers, DeCoILFNet fused vs CPU software, printed in the paper's
//! format.
//!
//! Run: `cargo run --release --example vgg16_pipeline`

use decoilfnet::accel::{Engine, FusionPlan, Weights};
use decoilfnet::baselines::cpu_ref::{forward_timed, CpuWeights};
use decoilfnet::config::{vgg16_prefix, AccelConfig, Network};
use decoilfnet::tensor::NdTensor;
use decoilfnet::util::table::{fmt_speedup, Table};

/// Paper Table II: (ending layer, CPU-caffe ms, GPU-caffe ms, DeCoILFNet ms).
const PAPER: &[(&str, f64, f64, f64)] = &[
    ("conv1_1", 114.54, 23.12, 26.76),
    ("conv1_2", 736.78, 27.42, 27.01),
    ("pool1", 769.37, 27.15, 27.06),
    ("conv2_1", 1011.71, 29.31, 28.08),
    ("conv2_2", 1282.42, 33.45, 41.46),
    ("pool2", 1442.47, 33.57, 41.49),
    ("conv3_1", 1637.43, 34.81, 41.95),
];

fn main() {
    let cfg = AccelConfig::paper_default();
    let full = vgg16_prefix();
    let engine = Engine::new(cfg.clone());

    // CPU baseline: one measured forward pass, cumulative per layer.
    println!("measuring CPU reference (im2col + blocked GEMM) ...");
    let cpu_w = CpuWeights::random(&full, 1);
    let input = NdTensor::random(&full.input.as_slice(), 7, -1.0, 1.0);
    let (_, cpu_cum) = forward_timed(&full, &cpu_w, &input);

    // DeCoILFNet: simulate each prefix fully fused (the paper's experiment
    // runs growing prefixes as separate configurations).
    let mut rows = Vec::new();
    for (i, layer) in full.layers.iter().enumerate() {
        let prefix = Network {
            name: format!("vgg[..={}]", layer.name()),
            input: full.input,
            layers: full.layers[..=i].to_vec(),
        };
        let w = Weights::random(&prefix, 1);
        let rep = engine.simulate(&prefix, &w, &FusionPlan::fully_fused(i + 1));
        rows.push((layer.name().to_string(), rep.ms_at(cfg.platform.freq_mhz)));
    }

    let mut t = Table::new(&[
        "ending layer",
        "CPU meas (ms)",
        "DeCoILF sim (ms)",
        "speedup",
        "paper CPU (ms)",
        "paper DeCoILF (ms)",
        "paper speedup",
    ])
    .title("Table II — first seven layers of VGG-16 (cumulative)")
    .label_col();
    for (i, (name, ours_ms)) in rows.iter().enumerate() {
        let cpu_ms = cpu_cum[i].1;
        let (pname, pcpu, _pgpu, pours) = PAPER[i];
        assert_eq!(&pname, &name.as_str());
        t.row(&[
            name.clone(),
            format!("{cpu_ms:.1}"),
            format!("{ours_ms:.2}"),
            fmt_speedup(cpu_ms / ours_ms),
            format!("{pcpu:.1}"),
            format!("{pours:.2}"),
            fmt_speedup(pcpu / pours),
        ]);
    }
    println!("{}", t.to_ascii());
    println!("shape check: accelerator ≫ CPU at every prefix; speedup grows with depth.");
}
