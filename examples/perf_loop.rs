//! Tight loop for profiling with `perf record`.
use decoilfnet::accel::{Engine, FusionPlan, Weights};
use decoilfnet::config::{tiny_vgg, vgg16_prefix, AccelConfig};
use decoilfnet::tensor::NdTensor;

fn main() {
    let mode = std::env::args().nth(1).unwrap_or_else(|| "sim".into());
    let e = Engine::new(AccelConfig::paper_default());
    match mode.as_str() {
        "sim" => {
            let net = vgg16_prefix();
            let w = Weights::random(&net, 1);
            for _ in 0..300 {
                std::hint::black_box(e.simulate(&net, &w, &FusionPlan::fully_fused(7)));
            }
        }
        _ => {
            let net = tiny_vgg();
            let w = Weights::random(&net, 1);
            let input = NdTensor::random(&net.input.as_slice(), 7, -1.0, 1.0);
            for _ in 0..150 {
                std::hint::black_box(e.forward_fx(&net, &w, &input));
            }
        }
    }
}
