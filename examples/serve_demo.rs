//! Serving demo, two tiers:
//!
//! 1. **Fleet simulation** (always runs): the cluster subsystem plans a
//!    multi-board shard of the VGG prefix, drives it with open-loop traffic,
//!    and reports throughput / latency / utilization under shared-DDR
//!    contention — replicated vs pipelined side by side.
//! 2. **Live threaded server** (needs `make artifacts`): the coordinator
//!    batching concurrent clients over the PJRT artifacts, with per-request
//!    plan routing and live metrics.
//!
//! Run: `cargo run --release --example serve_demo`

use std::path::PathBuf;
use std::time::{Duration, Instant};

use decoilfnet::config::{vgg16_prefix, AccelConfig, ClusterConfig, ShardMode};
use decoilfnet::coordinator::{simulate_cluster, BatchPolicy, Server, ServerConfig};
use decoilfnet::runtime::Runtime;

fn fleet_demo() -> Result<(), String> {
    let cfg = AccelConfig::paper_default();
    let net = vgg16_prefix();
    println!("== fleet simulation: {} on 4 boards ==", net.name);
    for mode in [ShardMode::Replicated, ShardMode::Pipelined] {
        let mut ccfg = ClusterConfig::fleet_default();
        ccfg.mode = mode;
        ccfg.requests = 128;
        let r = simulate_cluster(&cfg, &net, &ccfg)?;
        let avg_util = r.per_board.iter().map(|b| b.utilization).sum::<f64>()
            / r.per_board.len() as f64;
        println!(
            "{:>10}: {:7.1} req/s  p50 {:7.2} ms  p99 {:7.2} ms  util {:3.0}%  \
             ddr slowdown {:.2}x  link {:.2} MB",
            mode.as_str(),
            r.throughput_rps,
            r.p50_ms,
            r.p99_ms,
            100.0 * avg_util,
            r.ddr_slowdown,
            r.link_bytes_total as f64 / (1024.0 * 1024.0),
        );
    }
    println!();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    fleet_demo().map_err(anyhow::Error::msg)?;

    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("(skipping live-server demo: run `make artifacts` to enable it)");
        return Ok(());
    }

    let srv = Server::start(ServerConfig {
        artifacts_dir: artifacts.clone(),
        network: "tiny-vgg".into(),
        default_plan: "fused".into(),
        batch: BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        },
    })?;
    println!("server up (tiny-vgg, default plan: fused)");

    let rt = Runtime::load(&artifacts, "tiny-vgg")?;
    let (input, golden) = rt.golden()?;

    // 6 concurrent clients × 8 requests, alternating plan routing.
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..6 {
        let h = srv.handle.clone();
        let input = input.clone();
        let golden = golden.clone();
        joins.push(std::thread::spawn(move || {
            for r in 0..8 {
                let plan = match (c + r) % 3 {
                    0 => Some("fused"),
                    1 => Some("unfused"),
                    _ => Some("split232"),
                };
                let resp = h.submit(input.clone(), plan).wait().unwrap();
                let out = resp.result.expect("inference failed");
                let diff = out.max_abs_diff(&golden);
                assert!(diff < 1e-3, "plan {:?} diverged: {diff}", resp.plan);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let wall = t0.elapsed();

    println!("{}", srv.handle.metrics_json());
    println!(
        "48 requests across 3 plans in {:.3} s = {:.1} req/s — all matched golden",
        wall.as_secs_f64(),
        48.0 / wall.as_secs_f64()
    );
    srv.shutdown();
    Ok(())
}
