//! Serving demo, six tiers:
//!
//! 1. **Fleet simulation** (always runs): the cluster subsystem plans a
//!    multi-board shard of the VGG prefix, drives it with open-loop traffic,
//!    and reports throughput / latency / utilization under shared-DDR
//!    contention — replicated vs pipelined side by side.
//! 2. **Heterogeneous fleet + re-sharding** (always runs): a two-generation
//!    fleet starts on cuts balanced under a homogeneous assumption, traffic
//!    steps up mid-run, and the re-shard controller migrates to a plan that
//!    respects each board's clock — throughput recovers.
//! 3. **Multi-tenant priorities** (always runs): two tenants share two
//!    boards — a high-priority interactive stream with a 1 ms p99 SLO and a
//!    low-priority bulk tenant whose traffic spikes to a burst mid-run. The
//!    spike floods the fleet; preemption cuts the interactive tenant
//!    through, the bulk tenant absorbs the aborted batches.
//! 4. **Unified control plane** (always runs): a replica-capped interactive
//!    stream's rate doubles mid-run; the tenant-aware re-shard controller
//!    scales it onto both boards and the tail settles — shown in both
//!    restart and work-preserving (resume) preemption modes.
//! 5. **Fault tolerance** (always runs): a 3-board fleet loses the board
//!    hosting a pipelined chain's entry stage mid-run. In-flight work is
//!    re-queued under work-preserving preemption accounting, the severed
//!    chain is emergency-re-sharded onto the survivors, and the board is
//!    re-admitted when it recovers — nothing is lost, and the report shows
//!    per-tenant SLO attainment through the outage.
//! 6. **Graceful degradation** (always runs): a best-effort tenant floods
//!    a fleet that is simultaneously browned out (one board at 30% compute
//!    capacity). Overload admission sheds what cannot meet the best-effort
//!    deadline, shed clients retry with jittered exponential backoff and
//!    eventually abandon — while the policy-less interactive tenant is
//!    never shed and rides out both disturbances. Offered always equals
//!    completed + abandoned.
//! 7. **Live threaded server** (needs `make artifacts`): the coordinator
//!    batching concurrent clients over the PJRT artifacts, with per-request
//!    plan routing and live metrics.
//!
//! Run: `cargo run --release --example serve_demo`

use std::path::PathBuf;
use std::time::{Duration, Instant};

use decoilfnet::accel::latency::group_cost_estimate;
use decoilfnet::accel::{FusionPlan, Weights};
use decoilfnet::cluster::{
    balance_min_max, place_tenants, simulate_fleet_dynamic, simulate_fleet_multi_tenant,
    InterBoardLink, ShardPlan, TenantWorkload,
};
use decoilfnet::config::{
    tiny_vgg, vgg16_prefix, AccelConfig, ClusterConfig, FaultEvent, FaultScript, LoadStep,
    OverloadPolicy, Platform, PreemptMode, ReshardPolicy, RetryPolicy, ShardMode, SloPolicy,
    TenantSpec,
};
use decoilfnet::coordinator::{simulate_cluster, BatchPolicy, Server, ServerConfig};
use decoilfnet::runtime::Runtime;

fn fleet_demo() -> Result<(), String> {
    let cfg = AccelConfig::paper_default();
    let net = vgg16_prefix();
    println!("== fleet simulation: {} on 4 boards ==", net.name);
    for mode in [ShardMode::Replicated, ShardMode::Pipelined] {
        let mut ccfg = ClusterConfig::fleet_default();
        ccfg.mode = mode;
        ccfg.requests = 128;
        let r = simulate_cluster(&cfg, &net, &ccfg)?;
        let avg_util = r.per_board.iter().map(|b| b.utilization).sum::<f64>()
            / r.per_board.len() as f64;
        println!(
            "{:>10}: {:7.1} req/s  p50 {:7.2} ms  p99 {:7.2} ms  util {:3.0}%  \
             ddr slowdown {:.2}x  link {:.2} MB",
            mode.as_str(),
            r.throughput_rps,
            r.p50_ms,
            r.p99_ms,
            100.0 * avg_util,
            r.ddr_slowdown,
            r.link_bytes_total as f64 / (1024.0 * 1024.0),
        );
    }
    println!();
    Ok(())
}

/// Two fast boards, two older-generation boards; naive homogeneous cuts;
/// a 4× traffic step a quarter of the way in. The controller notices the
/// p99 blow-up, re-plans on the real fleet, pays the migration, recovers.
fn hetero_reshard_demo() -> Result<(), String> {
    let cfg = AccelConfig::paper_default();
    let net = vgg16_prefix();
    let weights = Weights::random(&net, 1);
    let slow = AccelConfig {
        platform: Platform::virtex7_older_gen(),
        ..cfg.clone()
    };
    let fleet = vec![cfg.clone(), cfg.clone(), slow.clone(), slow];
    let plan = FusionPlan::unfused(7);

    // Naive cuts: balance raw cycles as if every board ran the base clock.
    let totals: Vec<u64> = plan
        .groups()
        .iter()
        .map(|g| group_cost_estimate(&cfg, &net, g.clone()).total())
        .collect();
    let cuts = balance_min_max(&totals, fleet.len().min(totals.len()));
    let naive = ShardPlan::pipelined_fleet_with_cuts(&fleet, &net, &weights, &plan, &cuts);

    let mut ccfg = ClusterConfig::fleet_default();
    ccfg.boards = fleet.len();
    ccfg.mode = ShardMode::Pipelined;
    ccfg.aggregate_ddr_bytes_per_cycle = None;
    ccfg.requests = 512;
    ccfg.max_batch = 8;
    let link = InterBoardLink::new(ccfg.link_bytes_per_cycle, ccfg.link_latency_cycles);
    let naive_cap = naive.capacity_rps(ccfg.max_batch, &link, cfg.platform.freq_mhz);
    ccfg.arrival_rps = 0.4 * naive_cap;
    ccfg.load_steps = vec![LoadStep {
        at_request: 128,
        rps: 1.6 * naive_cap,
    }];
    ccfg.reshard = Some(ReshardPolicy::default_policy());

    println!("== heterogeneous fleet (2× 120 MHz + 2× 60 MHz), load step at request 128 ==");
    let r = simulate_fleet_dynamic(&cfg, &fleet, &net, &weights, naive.clone(), &ccfg);
    let mut frozen = ccfg.clone();
    frozen.reshard = None;
    let r_frozen = simulate_fleet_dynamic(&cfg, &fleet, &net, &weights, naive, &frozen);
    for e in &r.reshard_events {
        println!(
            "  reshard @ cycle {}: {} -> {} ({}; moved {:.2} MB)",
            e.at_cycle,
            e.from,
            e.to,
            e.reason,
            e.migration_bytes as f64 / (1024.0 * 1024.0)
        );
    }
    println!(
        "  controller: {:7.1} req/s p99 {:8.2} ms   frozen naive plan: {:7.1} req/s p99 {:8.2} ms",
        r.throughput_rps, r.p99_ms, r_frozen.throughput_rps, r_frozen.p99_ms
    );
    println!();
    Ok(())
}

/// Two tenants, two boards, strict priorities: the interactive tenant's
/// Poisson stream holds a 1 ms p99 SLO while the bulk tenant's mid-run
/// burst floods the fleet and absorbs every preemption.
fn multi_tenant_demo() -> Result<(), String> {
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone()];
    let specs = vec![
        TenantSpec {
            name: "interactive".to_string(),
            network: tiny_vgg(),
            weights_seed: 1,
            arrival_rps: 1500.0,
            requests: 48,
            load_steps: vec![],
            mode: ShardMode::Replicated,
            replicas: None,
            slo: SloPolicy {
                p99_ms: 1.0,
                priority: 2,
                weight: 1.0,
                overload: None,
            },
        },
        TenantSpec {
            name: "bulk".to_string(),
            network: tiny_vgg(),
            weights_seed: 2,
            arrival_rps: 800.0,
            requests: 96,
            load_steps: vec![LoadStep {
                at_request: 16,
                rps: f64::INFINITY,
            }],
            mode: ShardMode::Replicated,
            replicas: None,
            slo: SloPolicy {
                p99_ms: 2.0,
                priority: 0,
                weight: 1.0,
                overload: None,
            },
        },
    ];
    let weights: Vec<Weights> = specs
        .iter()
        .map(|s| Weights::random(&s.network, s.weights_seed))
        .collect();
    let fused = FusionPlan::fully_fused(7);
    let workloads: Vec<TenantWorkload> = specs
        .iter()
        .zip(&weights)
        .map(|(s, w)| TenantWorkload {
            name: &s.name,
            net: &s.network,
            weights: w,
            plan: &fused,
            mode: s.mode,
            priority: s.slo.priority,
            replicas: s.replicas,
        })
        .collect();
    let plans = place_tenants(&fleet, &workloads)?;

    let mut ccfg = ClusterConfig::fleet_default();
    ccfg.boards = 2;
    ccfg.aggregate_ddr_bytes_per_cycle = None;
    ccfg.link_bytes_per_cycle = f64::INFINITY;
    ccfg.link_latency_cycles = 0;
    ccfg.max_batch = 8;
    ccfg.max_wait_us = 0.0;
    ccfg.seed = 7;

    println!(
        "== multi-tenant priorities: 2 tenants on 2 shared boards, bulk spike at request 16 =="
    );
    let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &weights, &plans, &ccfg);
    for t in &r.tenants {
        println!(
            "  {:>12} (prio {}): {:7.1} req/s  p50 {:7.3} ms  p99 {:7.3} ms  \
             slo {:6.1} ms [{}]  preempted {} time(s)",
            t.name,
            t.priority,
            t.throughput_rps,
            t.p50_ms,
            t.p99_ms,
            t.slo_p99_ms,
            if t.slo_met { "MET" } else { "MISSED" },
            t.preemptions,
        );
    }
    println!(
        "  fleet: {} requests over {} boards, ddr slowdown {:.2}x",
        r.completed, r.boards, r.ddr_slowdown
    );
    println!();
    Ok(())
}

/// The unified control plane: a replica-capped interactive stream whose
/// rate doubles mid-run past its board's capacity. The tenant-aware
/// controller sees its window p99 blow the SLO, uncaps it onto both boards
/// (billing the weight migration), and the tail settles again — with
/// work-preserving preemption saving cycles over full restarts throughout.
fn unified_control_plane_demo() -> Result<(), String> {
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone()];
    let specs = vec![
        TenantSpec {
            name: "stream".to_string(),
            network: tiny_vgg(),
            weights_seed: 1,
            arrival_rps: 7500.0,
            requests: 320,
            load_steps: vec![LoadStep {
                at_request: 96,
                rps: 15000.0,
            }],
            mode: ShardMode::Replicated,
            replicas: Some(1),
            slo: SloPolicy {
                p99_ms: 0.5,
                priority: 2,
                weight: 1.0,
                overload: None,
            },
        },
        TenantSpec {
            name: "bulk".to_string(),
            network: tiny_vgg(),
            weights_seed: 2,
            arrival_rps: f64::INFINITY,
            requests: 64,
            load_steps: vec![],
            mode: ShardMode::Replicated,
            replicas: None,
            slo: SloPolicy {
                p99_ms: 5000.0,
                priority: 0,
                weight: 1.0,
                overload: None,
            },
        },
    ];
    let weights: Vec<Weights> = specs
        .iter()
        .map(|s| Weights::random(&s.network, s.weights_seed))
        .collect();
    let fused = FusionPlan::fully_fused(7);
    let workloads: Vec<TenantWorkload> = specs
        .iter()
        .zip(&weights)
        .map(|(s, w)| TenantWorkload {
            name: &s.name,
            net: &s.network,
            weights: w,
            plan: &fused,
            mode: s.mode,
            priority: s.slo.priority,
            replicas: s.replicas,
        })
        .collect();
    let plans = place_tenants(&fleet, &workloads)?;

    let mut ccfg = ClusterConfig::fleet_default();
    ccfg.boards = 2;
    ccfg.aggregate_ddr_bytes_per_cycle = None;
    ccfg.max_batch = 8;
    ccfg.max_wait_us = 0.0;
    ccfg.seed = 11;
    ccfg.reshard = Some(ReshardPolicy {
        window: 48,
        util_skew: 0.9,
        p99_ms: 50.0, // per-tenant SLOs supersede this on the unified path
        cooldown_windows: 1,
        migration_factor: 1.0,
    });

    println!(
        "== unified control plane: capped stream, rate 7.5k -> 15k req/s at request 96 =="
    );
    for mode in [PreemptMode::Restart, PreemptMode::Resume] {
        let mut c = ccfg.clone();
        c.preempt_mode = mode;
        let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &weights, &plans, &c);
        for e in &r.reshard_events {
            println!(
                "  [{}] reshard @ cycle {} tenant {}: {} -> {} ({})",
                mode.as_str(),
                e.at_cycle,
                e.tenant.as_deref().unwrap_or("?"),
                e.from,
                e.to,
                e.reason
            );
        }
        let billed: u64 = r.per_board.iter().map(|b| b.busy_cycles).sum();
        let stream = &r.tenants[0];
        println!(
            "  [{}] stream p99 {:7.3} ms  tail p99 {:7.3} ms  bulk preempted {}  \
             billed {} cycles",
            mode.as_str(),
            stream.p99_ms,
            stream.tail_p99_ms.unwrap_or(f64::NAN),
            r.tenants[1].preemptions,
            billed,
        );
    }
    println!();
    Ok(())
}

/// Fault tolerance: a 3-board fleet, a replicated interactive tenant and a
/// pipelined bulk chain. The board hosting the chain's entry stage dies a
/// third of the way in and recovers later: its in-flight items are thrown
/// back to their queues, the severed chain is emergency-re-sharded onto
/// the two survivors, and the recovered board is re-admitted at the next
/// controller window — with every request still completing.
fn fault_tolerance_demo() -> Result<(), String> {
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone(), cfg.clone()];
    let specs = vec![
        TenantSpec {
            name: "interactive".to_string(),
            network: tiny_vgg(),
            weights_seed: 1,
            arrival_rps: 800.0,
            requests: 48,
            load_steps: vec![],
            mode: ShardMode::Replicated,
            replicas: None,
            slo: SloPolicy {
                p99_ms: 2.0,
                priority: 2,
                weight: 1.0,
                overload: None,
            },
        },
        TenantSpec {
            name: "bulk-chain".to_string(),
            network: tiny_vgg(),
            weights_seed: 2,
            arrival_rps: 300.0,
            requests: 32,
            load_steps: vec![],
            mode: ShardMode::Pipelined,
            replicas: None,
            slo: SloPolicy {
                p99_ms: 5.0,
                priority: 1,
                weight: 1.0,
                overload: None,
            },
        },
    ];
    let weights: Vec<Weights> = specs
        .iter()
        .map(|s| Weights::random(&s.network, s.weights_seed))
        .collect();
    let fused = FusionPlan::fully_fused(7);
    let unfused = FusionPlan::unfused(7);
    let workloads: Vec<TenantWorkload> = specs
        .iter()
        .zip(&weights)
        .map(|(s, w)| TenantWorkload {
            name: &s.name,
            net: &s.network,
            weights: w,
            plan: match s.mode {
                ShardMode::Replicated => &fused,
                ShardMode::Pipelined => &unfused,
            },
            mode: s.mode,
            priority: s.slo.priority,
            replicas: s.replicas,
        })
        .collect();
    let plans = place_tenants(&fleet, &workloads)?;
    // Kill the board the chain enters on — the worst case for the chain.
    let chain_entry = plans[1].shards[0].board;

    let mut ccfg = ClusterConfig::fleet_default();
    ccfg.boards = 3;
    ccfg.aggregate_ddr_bytes_per_cycle = None;
    ccfg.link_bytes_per_cycle = 16.0;
    ccfg.link_latency_cycles = 0;
    ccfg.max_batch = 4;
    ccfg.max_wait_us = 0.0;
    ccfg.seed = 11;
    ccfg.preempt_mode = PreemptMode::Resume;
    ccfg.reshard = Some(ReshardPolicy {
        window: 16,
        util_skew: 0.9,
        p99_ms: 50.0,
        cooldown_windows: 1,
        migration_factor: 0.0,
    });
    ccfg.tenants = specs.clone();
    ccfg.faults = Some(FaultScript {
        events: vec![FaultEvent::BoardDown {
            board: chain_entry,
            at_ms: 30.0,
            recover_ms: Some(60.0),
        }],
    });

    println!(
        "== fault tolerance: board {chain_entry} (chain entry stage) down 30 -> 60 ms =="
    );
    let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &weights, &plans, &ccfg);
    let f = r.faults.as_ref().expect("script armed");
    println!(
        "  {} failure(s), {} recovery(ies), {} emergency reshard(s), \
         {} item(s) requeued, downtime {} cycles",
        f.board_failures, f.board_recoveries, f.emergency_reshards, f.items_requeued,
        f.downtime_cycles,
    );
    if let (Some(pre), Some(post)) = (f.pre_fault_p99_ms, f.recovery_p99_ms) {
        println!(
            "  pre-fault p99 {pre:.3} ms -> post-recovery p99 {post:.3} ms ({:.2}x)",
            post / pre
        );
    }
    for t in &r.tenants {
        println!(
            "  {:>12}: {}/{} completed  p99 {:7.3} ms  slo [{}]  \
             {:.0}% within SLO through the outage",
            t.name,
            t.completed,
            t.requests,
            t.p99_ms,
            if t.slo_met { "MET" } else { "MISSED" },
            100.0 * t.slo_attainment_outage.unwrap_or(1.0),
        );
    }
    assert_eq!(r.completed, 48 + 32, "the outage loses nothing");
    println!();
    Ok(())
}

/// Graceful degradation: a 256-request best-effort burst hits a 2-board
/// fleet whose board 0 browns out to 30% compute capacity mid-flood. The
/// flooder carries an overload policy — admission predicts each request's
/// wait from the DRR deficit and board occupancy and sheds what cannot
/// make the deadline; shed clients retry on jittered exponential backoff
/// and abandon once the budget is spent. The interactive tenant carries no
/// policy, is never shed, and keeps its SLO through flood + brownout.
fn overload_demo() -> Result<(), String> {
    let cfg = AccelConfig::paper_default();
    let fleet = vec![cfg.clone(), cfg.clone()];
    let specs = vec![
        TenantSpec {
            name: "interactive".to_string(),
            network: tiny_vgg(),
            weights_seed: 1,
            arrival_rps: 2000.0,
            requests: 64,
            load_steps: vec![],
            mode: ShardMode::Replicated,
            replicas: None,
            slo: SloPolicy {
                p99_ms: 1.0,
                priority: 2,
                weight: 1.0,
                overload: None,
            },
        },
        TenantSpec {
            name: "best-effort".to_string(),
            network: tiny_vgg(),
            weights_seed: 2,
            arrival_rps: f64::INFINITY,
            requests: 256,
            load_steps: vec![],
            mode: ShardMode::Replicated,
            replicas: None,
            slo: SloPolicy {
                p99_ms: 5000.0,
                priority: 0,
                weight: 1.0,
                overload: Some(OverloadPolicy {
                    deadline_ms: 2.0,
                    max_queue: 8,
                    retry: RetryPolicy {
                        max_attempts: 3,
                        backoff_base_ms: 0.2,
                        jitter: 0.5,
                    },
                }),
            },
        },
    ];
    let weights: Vec<Weights> = specs
        .iter()
        .map(|s| Weights::random(&s.network, s.weights_seed))
        .collect();
    let fused = FusionPlan::fully_fused(7);
    let workloads: Vec<TenantWorkload> = specs
        .iter()
        .zip(&weights)
        .map(|(s, w)| TenantWorkload {
            name: &s.name,
            net: &s.network,
            weights: w,
            plan: &fused,
            mode: s.mode,
            priority: s.slo.priority,
            replicas: s.replicas,
        })
        .collect();
    let plans = place_tenants(&fleet, &workloads)?;

    let mut ccfg = ClusterConfig::fleet_default();
    ccfg.boards = 2;
    ccfg.aggregate_ddr_bytes_per_cycle = None;
    ccfg.link_bytes_per_cycle = f64::INFINITY;
    ccfg.link_latency_cycles = 0;
    ccfg.max_batch = 8;
    ccfg.max_wait_us = 0.0;
    ccfg.seed = 7;
    ccfg.tenants = specs.clone();
    ccfg.faults = Some(FaultScript {
        events: vec![FaultEvent::ComputeDegrade {
            board: 0,
            capacity_fraction: 0.3,
            at_ms: 0.5,
            recover_ms: Some(3.0),
        }],
    });

    println!(
        "== graceful degradation: 256-req best-effort flood, board 0 at 30% capacity \
         0.5 -> 3 ms =="
    );
    let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &weights, &plans, &ccfg);
    for t in &r.tenants {
        println!(
            "  {:>12}: {:3}/{:3} completed  shed {:3}  retried {:3}  abandoned {:3}  \
             goodput {:7.1} req/s  p99 {:7.3} ms [{}]",
            t.name,
            t.completed,
            t.requests,
            t.shed.unwrap_or(0),
            t.retried.unwrap_or(0),
            t.abandoned.unwrap_or(0),
            t.goodput_rps.unwrap_or(0.0),
            t.p99_ms,
            if t.slo_met { "MET" } else { "MISSED" },
        );
        assert_eq!(
            t.completed as u64 + t.abandoned.unwrap_or(0),
            t.requests as u64,
            "offered == completed + abandoned"
        );
    }
    let f = r.faults.as_ref().expect("script armed");
    println!(
        "  fleet: {} shed, {} abandoned, goodput {:.1} req/s; {} compute degrade(s)",
        r.shed_total.unwrap_or(0),
        r.abandoned_total.unwrap_or(0),
        r.goodput_rps.unwrap_or(0.0),
        f.compute_degrades,
    );
    println!();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    fleet_demo().map_err(anyhow::Error::msg)?;
    hetero_reshard_demo().map_err(anyhow::Error::msg)?;
    multi_tenant_demo().map_err(anyhow::Error::msg)?;
    unified_control_plane_demo().map_err(anyhow::Error::msg)?;
    fault_tolerance_demo().map_err(anyhow::Error::msg)?;
    overload_demo().map_err(anyhow::Error::msg)?;

    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("(skipping live-server demo: run `make artifacts` to enable it)");
        return Ok(());
    }

    let srv = Server::start(ServerConfig {
        artifacts_dir: artifacts.clone(),
        network: "tiny-vgg".into(),
        default_plan: "fused".into(),
        batch: BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        },
    })?;
    println!("server up (tiny-vgg, default plan: fused)");

    let rt = Runtime::load(&artifacts, "tiny-vgg")?;
    let (input, golden) = rt.golden()?;

    // 6 concurrent clients × 8 requests, alternating plan routing.
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..6 {
        let h = srv.handle.clone();
        let input = input.clone();
        let golden = golden.clone();
        joins.push(std::thread::spawn(move || {
            for r in 0..8 {
                let plan = match (c + r) % 3 {
                    0 => Some("fused"),
                    1 => Some("unfused"),
                    _ => Some("split232"),
                };
                let resp = h.submit(input.clone(), plan).wait().unwrap();
                let out = resp.result.expect("inference failed");
                let diff = out.max_abs_diff(&golden);
                assert!(diff < 1e-3, "plan {:?} diverged: {diff}", resp.plan);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let wall = t0.elapsed();

    println!("{}", srv.handle.metrics_json());
    println!(
        "48 requests across 3 plans in {:.3} s = {:.1} req/s — all matched golden",
        wall.as_secs_f64(),
        48.0 / wall.as_secs_f64()
    );
    srv.shutdown();
    Ok(())
}
