//! Serving demo: the coordinator batching concurrent clients over the PJRT
//! artifacts, with per-request plan routing and live metrics.
//!
//! Requires `make artifacts` (tiny-vgg artifacts).
//! Run: `cargo run --release --example serve_demo`

use std::path::PathBuf;
use std::time::{Duration, Instant};

use decoilfnet::coordinator::{BatchPolicy, Server, ServerConfig};
use decoilfnet::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    let srv = Server::start(ServerConfig {
        artifacts_dir: artifacts.clone(),
        network: "tiny-vgg".into(),
        default_plan: "fused".into(),
        batch: BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        },
    })?;
    println!("server up (tiny-vgg, default plan: fused)");

    let rt = Runtime::load(&artifacts, "tiny-vgg")?;
    let (input, golden) = rt.golden()?;

    // 6 concurrent clients × 8 requests, alternating plan routing.
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..6 {
        let h = srv.handle.clone();
        let input = input.clone();
        let golden = golden.clone();
        joins.push(std::thread::spawn(move || {
            for r in 0..8 {
                let plan = match (c + r) % 3 {
                    0 => Some("fused"),
                    1 => Some("unfused"),
                    _ => Some("split232"),
                };
                let resp = h.submit(input.clone(), plan).wait().unwrap();
                let out = resp.result.expect("inference failed");
                let diff = out.max_abs_diff(&golden);
                assert!(diff < 1e-3, "plan {:?} diverged: {diff}", resp.plan);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let wall = t0.elapsed();

    println!("{}", srv.handle.metrics_json());
    println!(
        "48 requests across 3 plans in {:.3} s = {:.1} req/s — all matched golden",
        wall.as_secs_f64(),
        48.0 / wall.as_secs_f64()
    );
    srv.shutdown();
    Ok(())
}
